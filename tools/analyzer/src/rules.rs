//! The five semantic rules. Each closes a specific evasion or blind
//! spot of the grep gates (tools/lint.sh R1–R3):
//!
//! * **A1** facade enforcement — *any* import path resolving to
//!   `std::sync` / `std::thread` outside `rust/src/sync/`, including
//!   grouped (`use std::{sync, thread}`), aliased (`use std::sync as
//!   s`), renamed-root (`use std as s`) and fully-qualified expression
//!   paths. R1's regex missed the grouped form entirely.
//! * **A2** hot-path panic ban — `unwrap` / `expect` / `panic!` /
//!   indexing-with-an-integer-literal in the *non-test* code of the
//!   per-frame files, with real item-level `#[cfg(test)]` span
//!   detection (R2's awk stopped at the first test marker, so anything
//!   after a test module was invisible).
//! * **A3** untimed condvar waits need a `loom-verified:` annotation
//!   attached to the wait's statement, and the annotation must name a
//!   loom model that actually exists in the crate (R3 accepted any
//!   text within 8 lines).
//! * **A4** guard-across-blocking — a lock guard live across a
//!   blocking call (`.wait(…)` on *another* guard, `sleep`,
//!   `busy_wait`, `.join()`, channel `send`/`recv`) in the same block.
//!   Grep cannot see liveness at all.
//! * **A5** custody exhaustiveness — a `match` whose arms name a
//!   custody enum (`Admission`, `QosClass`, `EvictPolicy`,
//!   `SegmentAction`) may not carry a wildcard / catch-all arm: adding
//!   a variant must break the build at every accounting site, not be
//!   silently absorbed.

use std::collections::BTreeSet;

use crate::config::Config;
use crate::lexer::Kind;
use crate::model::FileModel;

#[derive(Debug, Clone)]
pub struct Finding {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
}

impl Finding {
    pub fn render(&self) -> String {
        format!("{}:{}: {}: {}", self.file, self.line, self.rule, self.msg)
    }
}

/// Crate-wide facts the per-file passes need: today, the set of loom
/// model fns (`fn loom_*`), so A3 can verify an annotation names a
/// model that exists.
#[derive(Default)]
pub struct Ctx {
    pub loom_fns: BTreeSet<String>,
}

impl Ctx {
    pub fn scan(models: &[FileModel]) -> Ctx {
        let mut loom_fns = BTreeSet::new();
        for m in models {
            for i in 0..m.ncode().saturating_sub(1) {
                if m.tok(i).is_ident("fn") {
                    let nx = m.tok(i + 1);
                    if nx.kind == Kind::Ident && nx.text.starts_with("loom_") {
                        loom_fns.insert(nx.text.clone());
                    }
                }
            }
        }
        Ctx { loom_fns }
    }
}

pub fn analyze_file(m: &FileModel, cfg: &Config, ctx: &Ctx) -> Vec<Finding> {
    let mut out = Vec::new();
    if cfg.is_facade(&m.rel) {
        // the facade is the audited boundary: it is the one place raw
        // std primitives (and the primitive wait it wraps) may live
        return out;
    }
    rule_a1(m, &mut out);
    if cfg.is_hot(&m.rel) {
        rule_a2(m, &mut out);
    }
    rule_a3(m, ctx, &mut out);
    rule_a4(m, &mut out);
    rule_a5(m, cfg, &mut out);
    out.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    out
}

fn push(out: &mut Vec<Finding>, m: &FileModel, line: usize, rule: &'static str, msg: String) {
    out.push(Finding { file: m.rel.clone(), line, rule, msg });
}

// ===================================================================== A1

/// One leaf of an expanded use tree.
struct UseLeaf {
    segs: Vec<String>,
    alias: Option<String>,
    /// code index of the leaf's terminal token (for attachment/line).
    at: usize,
}

/// Parse one use-tree element starting at code index `i` with `prefix`
/// already consumed; append leaves; return the index of the token that
/// terminated the element (`,`, `}`, `;` — not consumed).
fn parse_use_tree(m: &FileModel, mut i: usize, prefix: &[String], leaves: &mut Vec<UseLeaf>) -> usize {
    let mut segs = prefix.to_vec();
    while i < m.ncode() {
        let t = m.tok(i);
        if t.is_punct(':') && m.is_path_sep(i) {
            i += 2; // `::` separator (also leading `::`)
            continue;
        }
        if t.is_punct('{') {
            // group: subtrees separated by commas
            i += 1;
            loop {
                if i >= m.ncode() {
                    return i;
                }
                if m.tok(i).is_punct('}') {
                    return i + 1;
                }
                i = parse_use_tree(m, i, &segs, leaves);
                if i < m.ncode() && m.tok(i).is_punct(',') {
                    i += 1;
                    continue;
                }
                if i < m.ncode() && m.tok(i).is_punct('}') {
                    return i + 1;
                }
                return i; // malformed — bail without looping forever
            }
        }
        if t.is_punct('*') {
            segs.push("*".into());
            leaves.push(UseLeaf { segs, alias: None, at: i });
            return i + 1;
        }
        if t.is_ident("as") {
            let alias = if i + 1 < m.ncode() && m.tok(i + 1).kind == Kind::Ident {
                Some(m.tok(i + 1).text.clone())
            } else {
                None
            };
            leaves.push(UseLeaf { segs, alias, at: i });
            return i + 2;
        }
        if t.kind == Kind::Ident {
            if t.text != "self" {
                segs.push(t.text.clone());
            }
            i += 1;
            continue;
        }
        // `,` `}` `;` or anything unexpected: this element is complete
        if !segs.is_empty() && segs != prefix {
            leaves.push(UseLeaf { segs, alias: None, at: i.saturating_sub(1) });
        } else if segs == prefix && !prefix.is_empty() {
            // bare `self` leaf: the prefix itself
            leaves.push(UseLeaf { segs, alias: None, at: i.saturating_sub(1) });
        }
        return i;
    }
    i
}

fn rule_a1(m: &FileModel, out: &mut Vec<Finding>) {
    let mut use_spans: Vec<(usize, usize)> = Vec::new();
    let mut k = 0usize;
    while k < m.ncode() {
        if m.tok(k).is_ident("use") {
            let start = k;
            let mut leaves = Vec::new();
            let mut i = parse_use_tree(m, k + 1, &[], &mut leaves);
            while i < m.ncode() && !m.tok(i).is_punct(';') {
                i += 1;
            }
            use_spans.push((start, i));
            for leaf in &leaves {
                let s = &leaf.segs;
                let banned = (s.len() >= 2
                    && s[0] == "std"
                    && (s[1] == "sync" || s[1] == "thread" || s[1] == "*"))
                    || (s.len() == 1 && s[0] == "std" && leaf.alias.is_some());
                if banned && !m.allowed(start, "lint:allow(raw-sync)") {
                    let path = s.join("::");
                    let ali = leaf
                        .alias
                        .as_ref()
                        .map(|a| format!(" (as `{a}`)"))
                        .unwrap_or_default();
                    push(
                        out,
                        m,
                        m.tok(leaf.at).line,
                        "A1",
                        format!(
                            "import resolves to `{path}`{ali} outside the sync facade — \
                             route through crate::sync so loom can model it \
                             (lint:allow(raw-sync) + why, if loom cannot)"
                        ),
                    );
                }
            }
            k = i + 1;
            continue;
        }
        k += 1;
    }
    // fully-qualified expression paths: `std::sync::…` / `::std::thread::…`
    let in_use = |i: usize| use_spans.iter().any(|&(a, b)| i >= a && i <= b);
    for i in 0..m.ncode().saturating_sub(3) {
        let t = m.tok(i);
        if t.is_ident("std")
            && m.is_path_sep(i + 1)
            && m.tok(i + 3).kind == Kind::Ident
            && matches!(m.tok(i + 3).text.as_str(), "sync" | "thread")
            && !in_use(i)
            && !m.allowed(i, "lint:allow(raw-sync)")
        {
            push(
                out,
                m,
                t.line,
                "A1",
                format!(
                    "fully-qualified `std::{}` path outside the sync facade — \
                     route through crate::sync so loom can model it",
                    m.tok(i + 3).text
                ),
            );
        }
    }
}

// ===================================================================== A2

fn rule_a2(m: &FileModel, out: &mut Vec<Finding>) {
    const ALLOW: &str = "lint:allow(panic)";
    for i in 0..m.ncode() {
        let t = m.tok(i);
        if m.test_line[t.line.min(m.test_line.len() - 1)] {
            continue;
        }
        let prev = |j: usize| j.checked_sub(1).map(|p| m.tok(p));
        let next = |j: usize| if j + 1 < m.ncode() { Some(m.tok(j + 1)) } else { None };
        if (t.is_ident("unwrap") || t.is_ident("expect"))
            && prev(i).map(|p| p.is_punct('.')).unwrap_or(false)
            && next(i).map(|n| n.is_punct('(')).unwrap_or(false)
            && !m.allowed(i, ALLOW)
        {
            push(
                out,
                m,
                t.line,
                "A2",
                format!(
                    ".{}() on the serving hot path — a panic here kills a worker and \
                     silently shrinks the pool; use `?`, lock_unpoisoned, or \
                     lint:allow(panic) + why dying is correct",
                    t.text
                ),
            );
        }
        if t.is_ident("panic")
            && next(i).map(|n| n.is_punct('!')).unwrap_or(false)
            && !m.allowed(i, ALLOW)
        {
            push(
                out,
                m,
                t.line,
                "A2",
                "panic! on the serving hot path — return an error or annotate \
                 lint:allow(panic) + why dying is correct"
                    .into(),
            );
        }
        if t.is_punct('[')
            && prev(i)
                .map(|p| p.kind == Kind::Ident || p.is_punct(')') || p.is_punct(']'))
                .unwrap_or(false)
            && next(i).map(|n| n.is_plain_int()).unwrap_or(false)
            && i + 2 < m.ncode()
            && m.tok(i + 2).is_punct(']')
            && !m.allowed(i, ALLOW)
        {
            push(
                out,
                m,
                t.line,
                "A2",
                format!(
                    "indexing with integer literal `[{}]` on the serving hot path — \
                     out-of-bounds panics kill the worker; use .get()/.first() or \
                     lint:allow(panic) + the invariant that bounds it",
                    m.tok(i + 1).text
                ),
            );
        }
    }
}

// ===================================================================== A3

fn rule_a3(m: &FileModel, ctx: &Ctx, out: &mut Vec<Finding>) {
    for i in 0..m.ncode() {
        let t = m.tok(i);
        let dotted_wait = t.is_ident("wait")
            && i > 0
            && m.tok(i - 1).is_punct('.')
            && i + 1 < m.ncode()
            && m.tok(i + 1).is_punct('(');
        let facade_wait = t.is_ident("wait_unpoisoned")
            && i + 1 < m.ncode()
            && m.tok(i + 1).is_punct('(')
            && !(i > 0 && m.tok(i - 1).is_ident("fn"));
        if !dotted_wait && !facade_wait {
            continue;
        }
        let ann = m.attached_comments(i);
        if !ann.contains("loom-verified:") {
            push(
                out,
                m,
                t.line,
                "A3",
                "untimed condvar wait without a `loom-verified:` annotation naming \
                 the loom model that proves its wake protocol lost-wakeup-free \
                 (wait_timeout is exempt — a timeout is its own liveness floor)"
                    .into(),
            );
            continue;
        }
        let names = loom_names(&ann);
        if !names.iter().any(|n| ctx.loom_fns.contains(n)) {
            push(
                out,
                m,
                t.line,
                "A3",
                format!(
                    "`loom-verified:` annotation names no loom model that exists in \
                     the crate (named: {}; known models: {})",
                    if names.is_empty() { "none".into() } else { names.join(", ") },
                    ctx.loom_fns.iter().cloned().collect::<Vec<_>>().join(", ")
                ),
            );
        }
    }
}

/// Extract `loom_*` identifiers from annotation text.
fn loom_names(text: &str) -> Vec<String> {
    let chars: Vec<char> = text.chars().collect();
    let mut names = Vec::new();
    let mut i = 0;
    while i < chars.len() {
        let rest: String = chars[i..].iter().collect();
        if rest.starts_with("loom_") {
            let name: String = rest
                .chars()
                .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
                .collect();
            i += name.chars().count();
            if !names.contains(&name) {
                names.push(name);
            }
        } else {
            i += 1;
        }
    }
    names
}

// ===================================================================== A4

const GUARD_ALLOW: &str = "lint:allow(guard-across-blocking)";

fn rule_a4(m: &FileModel, out: &mut Vec<Finding>) {
    struct Guard {
        name: String,
        depth: i32,
        line: usize,
    }
    let mut guards: Vec<Guard> = Vec::new();
    let mut brace = 0i32;
    let mut i = 0usize;
    while i < m.ncode() {
        let t = m.tok(i);
        let on_test_line = m.test_line[t.line.min(m.test_line.len() - 1)];
        if t.is_punct('{') {
            brace += 1;
        } else if t.is_punct('}') {
            brace -= 1;
            guards.retain(|g| g.depth <= brace);
        } else if t.is_ident("drop")
            && i + 3 < m.ncode()
            && m.tok(i + 1).is_punct('(')
            && m.tok(i + 2).kind == Kind::Ident
            && m.tok(i + 3).is_punct(')')
        {
            let name = &m.tok(i + 2).text;
            guards.retain(|g| &g.name != name);
        } else if t.is_ident("let") && !on_test_line {
            if let Some((name, line)) = guard_binding(m, i) {
                guards.push(Guard { name, depth: brace, line });
            }
        } else if !on_test_line {
            if let Some((kind, consumed)) = blocking_site(m, i) {
                let offenders: Vec<&Guard> = guards
                    .iter()
                    .filter(|g| !consumed.contains(&g.name))
                    .collect();
                if !offenders.is_empty() && !m.allowed(i, GUARD_ALLOW) {
                    let held = offenders
                        .iter()
                        .map(|g| format!("`{}` (bound line {})", g.name, g.line))
                        .collect::<Vec<_>>()
                        .join(", ");
                    push(
                        out,
                        m,
                        t.line,
                        "A4",
                        format!(
                            "lock guard {held} held across blocking call `{kind}` — \
                             every thread contending that mutex now waits on this \
                             call too; drop the guard first, or annotate \
                             lint:allow(guard-across-blocking) + why it cannot \
                             deadlock"
                        ),
                    );
                }
            }
        }
        i += 1;
    }
}

/// `let [mut] NAME [: Ty] = <rhs containing a guard maker> ;` → NAME.
fn guard_binding(m: &FileModel, let_idx: usize) -> Option<(String, usize)> {
    let mut j = let_idx + 1;
    if j < m.ncode() && m.tok(j).is_ident("mut") {
        j += 1;
    }
    if j >= m.ncode() || m.tok(j).kind != Kind::Ident {
        return None; // tuple / struct pattern — out of scope
    }
    let name = m.tok(j).text.clone();
    let line = m.tok(j).line;
    j += 1;
    // optional `: Type` — scan to the `=` (stop at `;` = no initializer)
    let mut depth = 0i32;
    while j < m.ncode() {
        let t = m.tok(j);
        if t.is_punct('(') || t.is_punct('[') || t.is_punct('{') {
            depth += 1;
        } else if t.is_punct(')') || t.is_punct(']') || t.is_punct('}') {
            depth -= 1;
        } else if t.is_punct(';') && depth <= 0 {
            return None;
        } else if t.is_punct('=') && depth == 0 {
            // reject `==` (glued) — cannot appear here in valid code anyway
            break;
        }
        j += 1;
    }
    // RHS: up to `;` at depth 0 — does it make a guard? `{ … }` blocks
    // are skipped whole: a lock taken inside a block is bound to an
    // inner binding whose lifetime the block already ends, not to NAME
    // (the worker-loop `let job = { let q = lock…; … };` shape).
    let mut depth = 0i32;
    let mut k = j + 1;
    while k < m.ncode() {
        let t = m.tok(k);
        if t.is_punct('{') {
            let mut b = 1i32;
            k += 1;
            while k < m.ncode() && b > 0 {
                if m.tok(k).is_punct('{') {
                    b += 1;
                } else if m.tok(k).is_punct('}') {
                    b -= 1;
                }
                k += 1;
            }
            continue;
        }
        if t.is_punct('(') || t.is_punct('[') {
            depth += 1;
        } else if t.is_punct(')') || t.is_punct(']') || t.is_punct('}') {
            if depth == 0 {
                break; // `let` inside an expression position — bail
            }
            depth -= 1;
        } else if t.is_punct(';') && depth == 0 {
            break;
        } else if t.is_ident("lock_unpoisoned")
            || (t.is_ident("lock") && k > 0 && m.tok(k - 1).is_punct('.'))
        {
            return Some((name, line));
        }
        k += 1;
    }
    None
}

/// Is code index `i` a blocking call? Returns (label, idents passed as
/// arguments — a wait consumes the guard it is given, which is the
/// sanctioned hand-off, not a hold).
fn blocking_site(m: &FileModel, i: usize) -> Option<(String, Vec<String>)> {
    let t = m.tok(i);
    let next_is_paren = i + 1 < m.ncode() && m.tok(i + 1).is_punct('(');
    if !next_is_paren {
        return None;
    }
    let prev_dot = i > 0 && m.tok(i - 1).is_punct('.');
    let prev_fn = i > 0 && m.tok(i - 1).is_ident("fn");
    if prev_fn {
        return None;
    }
    let wait_family = (prev_dot && matches!(t.text.as_str(), "wait" | "wait_timeout"))
        || t.is_ident("wait_unpoisoned");
    let sleep_family = !prev_dot && matches!(t.text.as_str(), "sleep" | "busy_wait");
    let chan_family =
        prev_dot && matches!(t.text.as_str(), "join" | "send" | "recv" | "recv_timeout");
    if !wait_family && !sleep_family && !chan_family {
        return None;
    }
    let consumed = if wait_family {
        // idents in the argument list
        let mut depth = 0i32;
        let mut k = i + 1;
        let mut args = Vec::new();
        while k < m.ncode() {
            let a = m.tok(k);
            if a.is_punct('(') {
                depth += 1;
            } else if a.is_punct(')') {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            } else if a.kind == Kind::Ident {
                args.push(a.text.clone());
            }
            k += 1;
        }
        args
    } else {
        Vec::new()
    };
    Some((format!(".{}(", t.text), consumed))
}

// ===================================================================== A5

fn rule_a5(m: &FileModel, cfg: &Config, out: &mut Vec<Finding>) {
    const ALLOW: &str = "lint:allow(custody-wildcard)";
    for i in 0..m.ncode() {
        if !m.tok(i).is_ident("match") {
            continue;
        }
        // scrutinee: scan to the arms' `{` at paren/bracket depth 0
        let mut j = i + 1;
        let mut depth = 0i32;
        while j < m.ncode() {
            let t = m.tok(j);
            if t.is_punct('(') || t.is_punct('[') {
                depth += 1;
            } else if t.is_punct(')') || t.is_punct(']') {
                depth -= 1;
            } else if t.is_punct('{') && depth == 0 {
                break;
            }
            j += 1;
        }
        if j >= m.ncode() {
            continue;
        }
        let arms = split_arms(m, j);
        let custody = arms.iter().any(|a| {
            a.pattern.iter().any(|&p| {
                let t = m.tok(p);
                t.kind == Kind::Ident
                    && cfg.custody_enums.iter().any(|e| e == &t.text)
                    && m.is_path_sep(p + 1)
            })
        });
        if !custody {
            continue;
        }
        for a in &arms {
            // pattern up to a top-level `if` guard
            let core: Vec<&usize> = a
                .pattern
                .iter()
                .take_while(|&&p| !m.tok(p).is_ident("if"))
                .collect();
            if core.len() != 1 {
                continue;
            }
            let p = *core[0];
            let t = m.tok(p);
            let is_wild = t.is_ident("_");
            let is_binding = !is_wild
                && t.kind == Kind::Ident
                && t.text
                    .chars()
                    .next()
                    .map(|c| c.is_lowercase() || c == '_')
                    .unwrap_or(false)
                && !matches!(t.text.as_str(), "true" | "false");
            if (is_wild || is_binding) && !m.allowed(p, ALLOW) {
                let what = if is_wild {
                    "wildcard `_` arm".to_string()
                } else {
                    format!("catch-all binding `{}` arm", t.text)
                };
                push(
                    out,
                    m,
                    t.line,
                    "A5",
                    format!(
                        "{what} in a match over a custody enum — a new variant would \
                         be silently absorbed instead of forcing this accounting \
                         site to be revisited; enumerate every variant \
                         (lint:allow(custody-wildcard) + why, if the arm is \
                         genuinely variant-independent)"
                    ),
                );
            }
        }
    }
}

struct Arm {
    /// Code indices of the pattern tokens (before `=>`).
    pattern: Vec<usize>,
}

/// Split the arms of a match whose `{` is at code index `open`.
fn split_arms(m: &FileModel, open: usize) -> Vec<Arm> {
    let mut arms = Vec::new();
    let mut i = open + 1;
    let mut pat: Vec<usize> = Vec::new();
    let mut depth = 0i32; // over () [] {} inside the arms block
    let mut in_body = false;
    while i < m.ncode() {
        let t = m.tok(i);
        if t.is_punct('{') || t.is_punct('(') || t.is_punct('[') {
            depth += 1;
            // a body that IS a block: arm ends at its matching close
            if in_body && t.is_punct('{') && depth == 1 {
                // walk to the matching `}`
                let mut b = 1i32;
                let mut k = i + 1;
                while k < m.ncode() && b > 0 {
                    if m.tok(k).is_punct('{') {
                        b += 1;
                    } else if m.tok(k).is_punct('}') {
                        b -= 1;
                    }
                    k += 1;
                }
                i = k; // past the body block
                depth -= 1;
                in_body = false;
                arms.push(Arm { pattern: std::mem::take(&mut pat) });
                // optional trailing comma
                if i < m.ncode() && m.tok(i).is_punct(',') {
                    i += 1;
                }
                continue;
            }
        } else if t.is_punct('}') || t.is_punct(')') || t.is_punct(']') {
            if depth == 0 && t.is_punct('}') {
                // end of the match
                if !pat.is_empty() {
                    arms.push(Arm { pattern: std::mem::take(&mut pat) });
                }
                break;
            }
            depth -= 1;
        } else if depth == 0
            && t.is_punct('=')
            && i + 1 < m.ncode()
            && m.tok(i + 1).is_punct('>')
            && m.tok(i + 1).pos == t.pos + 1
        {
            in_body = true;
            i += 2;
            continue;
        } else if depth == 0 && t.is_punct(',') && in_body {
            arms.push(Arm { pattern: std::mem::take(&mut pat) });
            in_body = false;
            i += 1;
            continue;
        }
        if !in_body {
            pat.push(i);
        }
        i += 1;
    }
    arms
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(src: &str) -> Vec<Finding> {
        let m = FileModel::build("t.rs", src);
        let cfg = Config::fixtures("t.rs");
        let ctx = Ctx::scan(std::slice::from_ref(&m));
        analyze_file(&m, &cfg, &ctx)
    }

    #[test]
    fn grouped_and_aliased_imports_are_caught() {
        let f = run("use std::{collections::HashMap, sync::Mutex};\n");
        assert!(f.iter().any(|x| x.rule == "A1" && x.msg.contains("std::sync")));
        let f = run("use std::sync as s;\n");
        assert_eq!(f.iter().filter(|x| x.rule == "A1").count(), 1);
        let f = run("use std as s;\n");
        assert_eq!(f.iter().filter(|x| x.rule == "A1").count(), 1);
        let f = run("use ::std::thread::spawn;\n");
        assert_eq!(f.iter().filter(|x| x.rule == "A1").count(), 1);
    }

    #[test]
    fn benign_std_imports_pass() {
        let f = run("use std::collections::{HashMap, VecDeque};\nuse std::time::Duration;\n");
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn qualified_expression_path_is_caught() {
        let f = run("fn f() { let m = std::sync::Mutex::new(0); }\n");
        assert_eq!(f.iter().filter(|x| x.rule == "A1").count(), 1);
    }

    #[test]
    fn strings_and_comments_do_not_trip_a1() {
        let f = run("// std::sync in prose\nfn f() -> &'static str { \"std::thread\" }\n");
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn custody_wildcard_flags_but_value_position_does_not() {
        let bad = "fn f(a: Admission) -> u32 {\n    match a {\n        Admission::Delivered => 1,\n        _ => 0,\n    }\n}\n";
        let f = run(bad);
        assert_eq!(f.iter().filter(|x| x.rule == "A5").count(), 1, "{f:?}");
        // enum only on the arm VALUE side (from_u8 shape) — fine
        let good = "fn g(v: u8) -> Option<QosClass> {\n    match v {\n        0 => Some(QosClass::Realtime),\n        _ => None,\n    }\n}\n";
        let f = run(good);
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn guard_across_sleep_flags_and_wait_handoff_does_not() {
        let bad = "fn f() {\n    let g = lock_unpoisoned(&m);\n    thread::sleep(d);\n}\n";
        let f = run(bad);
        assert_eq!(f.iter().filter(|x| x.rule == "A4").count(), 1, "{f:?}");
        let good = "fn f() {\n    let mut g = lock_unpoisoned(&m);\n    g = wait_unpoisoned(&cv, g); // loom-verified: loom_model_x\n}\nmod loom_tests { fn loom_model_x() {} }\n";
        let f = run(good);
        assert!(f.is_empty(), "{f:?}");
    }
}
