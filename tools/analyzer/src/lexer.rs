//! A minimal, honest Rust lexer: exactly enough to tell code from
//! comments, strings, and char literals, so the rule passes can reason
//! about *tokens* instead of raw lines. This is what closes the grep
//! gates' known evasions — a `use std::{sync, thread}` inside a string
//! or comment is not code, and a grouped import is not hidden by line
//! formatting.
//!
//! Deliberately *not* a full parser: no expression trees, no types.
//! The structural layer (`model.rs`) adds item spans, test regions and
//! statement boundaries on top of this token stream; the rules consume
//! both. `syn` would give a true AST, but it would also make the gate
//! unbuildable on an offline machine — the same trade that keeps the
//! loom dependency target-gated (see `tools/analyzer/Cargo.toml`).

/// Token kind. Comments are kept in the stream (annotations like
/// `lint:allow(...)` and `loom-verified:` live in them); rule passes
/// that only care about code iterate `FileModel::code`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    Ident,
    Lifetime,
    Int,
    Float,
    Str,
    Char,
    Comment,
    Punct,
}

#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: Kind,
    pub text: String,
    /// 1-based line of the token's first character.
    pub line: usize,
    /// 1-based line of the token's last character (block comments and
    /// multi-line strings span lines).
    pub end_line: usize,
    /// Char offset of the token's first character — adjacency checks
    /// (`::` = two `:` puncts at consecutive offsets, `=>` likewise).
    pub pos: usize,
}

impl Tok {
    pub fn is_punct(&self, c: char) -> bool {
        self.kind == Kind::Punct && self.text.len() == c.len_utf8() && self.text.starts_with(c)
    }

    pub fn is_ident(&self, s: &str) -> bool {
        self.kind == Kind::Ident && self.text == s
    }

    /// An integer literal (decimal, hex, suffixed, underscored): the
    /// shape the indexing rule cares about — `v[0]`, `v[0x1F]`.
    pub fn is_plain_int(&self) -> bool {
        self.kind == Kind::Int
    }
}

fn ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn ident_cont(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Lex `src` into a full token stream (comments included).
pub fn lex(src: &str) -> Vec<Tok> {
    let cs: Vec<char> = src.chars().collect();
    let n = cs.len();
    let mut toks: Vec<Tok> = Vec::new();
    let mut i = 0usize;
    let mut line = 1usize;

    let text_of = |a: usize, b: usize, cs: &[char]| -> String { cs[a..b].iter().collect() };

    while i < n {
        let c = cs[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // ---------------------------------------------------- comments
        if c == '/' && i + 1 < n && cs[i + 1] == '/' {
            let start = i;
            while i < n && cs[i] != '\n' {
                i += 1;
            }
            toks.push(Tok {
                kind: Kind::Comment,
                text: text_of(start, i, &cs),
                line,
                end_line: line,
                pos: start,
            });
            continue;
        }
        if c == '/' && i + 1 < n && cs[i + 1] == '*' {
            let (start, start_line) = (i, line);
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if cs[i] == '\n' {
                    line += 1;
                    i += 1;
                } else if cs[i] == '/' && i + 1 < n && cs[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if cs[i] == '*' && i + 1 < n && cs[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            toks.push(Tok {
                kind: Kind::Comment,
                text: text_of(start, i, &cs),
                line: start_line,
                end_line: line,
                pos: start,
            });
            continue;
        }
        // ------------------------- raw strings / byte strings / r#idents
        if c == 'r' || c == 'b' {
            // possible prefixes: r" r#" b" b' br" br#" (and r#ident)
            let mut j = i;
            let mut is_raw = false;
            let mut is_byte_char = false;
            if cs[j] == 'b' {
                j += 1;
                if j < n && cs[j] == 'r' {
                    is_raw = true;
                    j += 1;
                } else if j < n && cs[j] == '\'' {
                    is_byte_char = true;
                }
            } else {
                // c == 'r'
                j += 1;
                is_raw = true;
            }
            if is_byte_char {
                // b'x' — lex as a char literal below by skipping the b
                let (start, start_line) = (i, line);
                i = j; // now at the quote
                i = lex_char_body(&cs, i, &mut line);
                toks.push(Tok {
                    kind: Kind::Char,
                    text: text_of(start, i, &cs),
                    line: start_line,
                    end_line: line,
                    pos: start,
                });
                continue;
            }
            let mut hashes = 0usize;
            let mut k = j;
            while is_raw && k < n && cs[k] == '#' {
                hashes += 1;
                k += 1;
            }
            let raw_string = is_raw && k < n && cs[k] == '"';
            let plain_string = !is_raw && j < n && cs[j] == '"' && cs[i] == 'b';
            if raw_string {
                // r##"..."## — scan for `"` + `hashes` hashes
                let (start, start_line) = (i, line);
                i = k + 1;
                'outer: while i < n {
                    if cs[i] == '\n' {
                        line += 1;
                        i += 1;
                        continue;
                    }
                    if cs[i] == '"' {
                        let mut h = 0usize;
                        while h < hashes && i + 1 + h < n && cs[i + 1 + h] == '#' {
                            h += 1;
                        }
                        if h == hashes {
                            i += 1 + hashes;
                            break 'outer;
                        }
                    }
                    i += 1;
                }
                toks.push(Tok {
                    kind: Kind::Str,
                    text: String::from("r\"…\""),
                    line: start_line,
                    end_line: line,
                    pos: start,
                });
                continue;
            }
            if plain_string {
                // b"..." — escaped string body
                let (start, start_line) = (i, line);
                i = j; // at the quote
                i = lex_str_body(&cs, i, &mut line);
                toks.push(Tok {
                    kind: Kind::Str,
                    text: text_of(start, i.min(n), &cs),
                    line: start_line,
                    end_line: line,
                    pos: start,
                });
                continue;
            }
            if is_raw && hashes == 1 && k < n && ident_start(cs[k]) {
                // r#ident — a raw identifier
                let start = i;
                let mut e = k;
                while e < n && ident_cont(cs[e]) {
                    e += 1;
                }
                toks.push(Tok {
                    kind: Kind::Ident,
                    text: text_of(k, e, &cs),
                    line,
                    end_line: line,
                    pos: start,
                });
                i = e;
                continue;
            }
            // plain identifier starting with r/b — fall through
        }
        // ------------------------------------------------------ strings
        if c == '"' {
            let (start, start_line) = (i, line);
            i = lex_str_body(&cs, i, &mut line);
            toks.push(Tok {
                kind: Kind::Str,
                text: text_of(start, i.min(n), &cs),
                line: start_line,
                end_line: line,
                pos: start,
            });
            continue;
        }
        // ------------------------------------- char literal vs lifetime
        if c == '\'' {
            if i + 1 < n && ident_start(cs[i + 1]) && cs[i + 1] != '\\' {
                // scan the ident; a closing quote right after means char
                let mut e = i + 1;
                while e < n && ident_cont(cs[e]) {
                    e += 1;
                }
                if e < n && cs[e] == '\'' && e > i + 1 {
                    // 'a' — char literal (only single-char idents close)
                    toks.push(Tok {
                        kind: Kind::Char,
                        text: text_of(i, e + 1, &cs),
                        line,
                        end_line: line,
                        pos: i,
                    });
                    i = e + 1;
                    continue;
                }
                toks.push(Tok {
                    kind: Kind::Lifetime,
                    text: text_of(i, e, &cs),
                    line,
                    end_line: line,
                    pos: i,
                });
                i = e;
                continue;
            }
            // '\n', '0', '{' … — a char literal body
            let (start, start_line) = (i, line);
            i = lex_char_body(&cs, i, &mut line);
            toks.push(Tok {
                kind: Kind::Char,
                text: text_of(start, i.min(n), &cs),
                line: start_line,
                end_line: line,
                pos: start,
            });
            continue;
        }
        // ------------------------------------------------------ numbers
        if c.is_ascii_digit() {
            let start = i;
            let mut saw_dot = false;
            while i < n && (ident_cont(cs[i])) {
                i += 1;
            }
            // fraction: `1.5` but not `1..5` and not `1.method()`
            if i + 1 < n
                && cs[i] == '.'
                && cs[i + 1].is_ascii_digit()
            {
                saw_dot = true;
                i += 1;
                while i < n && ident_cont(cs[i]) {
                    i += 1;
                }
            }
            // exponent sign: `1e-3`
            if i < n
                && (cs[i] == '+' || cs[i] == '-')
                && i > start
                && (cs[i - 1] == 'e' || cs[i - 1] == 'E')
                && i + 1 < n
                && cs[i + 1].is_ascii_digit()
            {
                saw_dot = true;
                i += 1;
                while i < n && ident_cont(cs[i]) {
                    i += 1;
                }
            }
            let text = text_of(start, i, &cs);
            let kind = if saw_dot || text.contains('.') { Kind::Float } else { Kind::Int };
            toks.push(Tok { kind, text, line, end_line: line, pos: start });
            continue;
        }
        // --------------------------------------------------- identifiers
        if ident_start(c) {
            let start = i;
            while i < n && ident_cont(cs[i]) {
                i += 1;
            }
            toks.push(Tok {
                kind: Kind::Ident,
                text: text_of(start, i, &cs),
                line,
                end_line: line,
                pos: start,
            });
            continue;
        }
        // ------------------------------------------------- single punct
        toks.push(Tok {
            kind: Kind::Punct,
            text: c.to_string(),
            line,
            end_line: line,
            pos: i,
        });
        i += 1;
    }
    toks
}

/// Consume an escaped string body starting at the opening quote; return
/// the index just past the closing quote.
fn lex_str_body(cs: &[char], mut i: usize, line: &mut usize) -> usize {
    let n = cs.len();
    i += 1; // opening quote
    while i < n {
        match cs[i] {
            '\\' => {
                // an escaped newline (line-continuation) still ends a
                // source line — keep the line counter honest
                if i + 1 < n && cs[i + 1] == '\n' {
                    *line += 1;
                }
                i += 2;
            }
            '"' => return i + 1,
            '\n' => {
                *line += 1;
                i += 1;
            }
            _ => i += 1,
        }
    }
    i
}

/// Consume a char-literal body starting at the opening quote; return
/// the index just past the closing quote.
fn lex_char_body(cs: &[char], mut i: usize, line: &mut usize) -> usize {
    let n = cs.len();
    i += 1; // opening quote
    while i < n {
        match cs[i] {
            '\\' => {
                if i + 1 < n && cs[i + 1] == '\n' {
                    *line += 1;
                }
                i += 2;
            }
            '\'' => return i + 1,
            '\n' => {
                *line += 1;
                i += 1;
            }
            _ => i += 1,
        }
    }
    i
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(Kind, String)> {
        lex(src).into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn comments_strings_and_chars_are_not_code() {
        let toks = kinds(r#"let s = "std::sync"; // std::thread"#);
        assert!(toks.iter().any(|(k, t)| *k == Kind::Str && t.contains("sync")));
        assert!(toks.iter().any(|(k, t)| *k == Kind::Comment && t.contains("thread")));
        // no Ident token spells sync/thread
        assert!(!toks
            .iter()
            .any(|(k, t)| *k == Kind::Ident && (t == "sync" || t == "thread")));
    }

    #[test]
    fn raw_strings_swallow_quotes_and_hashes() {
        let toks = kinds(r###"let x = r#"a "quoted" std::sync"# ; let y = 1;"###);
        let idents: Vec<&str> = toks
            .iter()
            .filter(|(k, _)| *k == Kind::Ident)
            .map(|(_, t)| t.as_str())
            .collect();
        assert_eq!(idents, ["let", "x", "let", "y"]);
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let toks = kinds("fn f<'a>(x: &'a str) -> char { 'a' }");
        assert_eq!(toks.iter().filter(|(k, _)| *k == Kind::Lifetime).count(), 2);
        assert_eq!(toks.iter().filter(|(k, _)| *k == Kind::Char).count(), 1);
    }

    #[test]
    fn nested_block_comments_close_correctly() {
        let toks = kinds("/* a /* b */ c */ ident");
        assert_eq!(toks.len(), 2);
        assert_eq!(toks[1].1, "ident");
    }

    #[test]
    fn ints_and_floats() {
        let toks = lex("a[0] + 1_000usize + 1.5 + 0x1F");
        let ints: Vec<&str> = toks
            .iter()
            .filter(|t| t.kind == Kind::Int)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(ints, ["0", "1_000usize", "0x1F"]);
        assert!(toks.iter().any(|t| t.kind == Kind::Float && t.text == "1.5"));
        assert!(lex("v[0]")[2].is_plain_int());
    }

    #[test]
    fn multiline_tokens_record_end_line() {
        let toks = lex("/* a\nb\nc */ x");
        assert_eq!(toks[0].line, 1);
        assert_eq!(toks[0].end_line, 3);
        assert_eq!(toks[1].line, 3);
    }
}
