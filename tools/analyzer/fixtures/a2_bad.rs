//! A2 bad: panics on the hot path — including one appended AFTER a
//! test module, the case the awk window could not see.

pub fn frame(v: &[u32], r: Result<u32, ()>) -> u32 {
    let first = v[0]; //~ A2
    let x = r.unwrap(); //~ A2
    let y = Some(1u32).expect("present"); //~ A2
    if first > 9 {
        panic!("bad frame"); //~ A2
    }
    x + y
}

#[cfg(test)]
mod tests {
    #[test]
    fn panics_are_fine_in_tests() {
        let v = [1u32, 2];
        assert_eq!(v[0], 1);
        Some(2u32).unwrap();
    }
}

pub fn appended_after_tests(v: &[u32]) -> u32 {
    v[1] //~ A2
}
