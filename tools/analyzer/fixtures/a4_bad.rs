//! A4 bad: lock guards live across blocking calls — a sleep, a
//! channel send, and a wait on a *different* guard.

pub fn sleep_with_guard(m: &Mutex) {
    let g = lock_unpoisoned(m);
    crate::sync::thread::sleep(SHORT); //~ A4
    drop(g);
}

pub fn send_with_guard(m: &Mutex, tx: &Sender) {
    let mut q = m.lock();
    q.push(1);
    tx.send(2); //~ A4
}

pub fn wait_on_other_guard(a: &Mutex, b: &Mutex, cv: &Condvar) {
    let held = lock_unpoisoned(a);
    let mut g = lock_unpoisoned(b);
    // loom-verified: loom_fixture_double_lock
    g = cv.wait(g); //~ A4
    drop(held);
    drop(g);
}

#[cfg(all(loom, test))]
mod loom_tests {
    fn loom_fixture_double_lock() {}
}
