//! A2 good: fallible access via `?` / `.first()`, a justified
//! annotation, and unrestricted panics inside test regions.

pub fn frame(v: &[u32], r: Result<u32, ()>) -> Result<u32, ()> {
    let first = *v.first().ok_or(())?;
    let x = r?;
    // lint:allow(panic) — hist is sized at construction; index 0 exists
    let h = v[0];
    Ok(first + x + h)
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_in_tests_is_fine() {
        Some(1u32).unwrap();
        let v = [7u32];
        assert_eq!(v[0], 7);
    }
}
