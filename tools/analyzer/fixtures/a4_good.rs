//! A4 good: drop before blocking, scope exit before blocking, the
//! sanctioned wait hand-off (the wait consumes the guard), and a
//! justified annotated hold.

pub fn drop_then_sleep(m: &Mutex) {
    let g = lock_unpoisoned(m);
    let snapshot = g.len();
    drop(g);
    crate::sync::thread::sleep(SHORT);
    let _ = snapshot;
}

pub fn scope_exit_then_send(m: &Mutex, tx: &Sender) {
    {
        let g = lock_unpoisoned(m);
        g.touch();
    }
    tx.send(3);
}

pub fn wait_handoff(m: &Mutex, cv: &Condvar) {
    let mut g = lock_unpoisoned(m);
    while !g.ready {
        // loom-verified: loom_fixture_handoff_model
        g = wait_unpoisoned(cv, g);
    }
}

pub fn annotated_hold(m: &Mutex, tx: &Sender) {
    let g = lock_unpoisoned(m);
    // lint:allow(guard-across-blocking) — tx is unbounded, the send
    // cannot block; the guard serialises snapshot order with send order
    tx.send(g.snapshot());
    drop(g);
}

#[cfg(all(loom, test))]
mod loom_tests {
    fn loom_fixture_handoff_model() {}
}
