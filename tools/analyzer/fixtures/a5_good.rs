//! A5 good: exhaustive custody matches; enums in value position
//! (the from_u8 shape) keep their open-ended wildcard; a justified
//! wildcard is allowed with a reason.

pub fn account(a: Admission) -> u32 {
    match a {
        Admission::Delivered => 1,
        Admission::Stale => 2,
        Admission::Backpressure => 3,
        Admission::Truncated => 4,
    }
}

pub fn from_u8(v: u8) -> Option<QosClass> {
    match v {
        0 => Some(QosClass::Realtime),
        1 => Some(QosClass::Standard),
        _ => None,
    }
}

pub fn display(q: QosClass) -> &'static str {
    match q {
        QosClass::Realtime => "rt",
        QosClass::Standard => "std",
        // lint:allow(custody-wildcard) — label only; the accounting
        // sites enumerate every variant, a display label need not
        _ => "other",
    }
}
