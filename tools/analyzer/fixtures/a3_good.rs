//! A3 good: an annotated untimed wait naming a model that exists in
//! this file, and a timeout-bounded wait that needs no annotation.

pub fn annotated(cv: &Condvar, mut g: Guard) -> Guard {
    while !g.ready {
        // loom-verified: loom_fixture_wake_model proves the notify
        // cannot be lost between the predicate check and the park
        g = cv.wait(g);
    }
    g
}

pub fn timed(cv: &Condvar, g: Guard) -> Guard {
    let (g, _timed_out) = cv.wait_timeout(g, SHORT);
    g
}

#[cfg(all(loom, test))]
mod loom_tests {
    fn loom_fixture_wake_model() {}
}
