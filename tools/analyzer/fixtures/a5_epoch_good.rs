//! A5 good (epoch custody): every retirement match enumerates the
//! `EpochOutcome` variants — adding a variant forces every accounting
//! site to pick its ledger column explicitly.

pub fn book(o: EpochOutcome) -> u32 {
    match o {
        EpochOutcome::Completed => 1,
        EpochOutcome::Failed => 2,
        EpochOutcome::Drained => 3,
    }
}

pub fn is_clean_retirement(o: EpochOutcome) -> bool {
    match o {
        EpochOutcome::Completed => true,
        EpochOutcome::Failed => false,
        EpochOutcome::Drained => false,
    }
}
