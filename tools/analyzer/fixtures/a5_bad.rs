//! A5 bad: wildcard and catch-all arms in matches over custody enums.

pub fn account(a: Admission) -> u32 {
    match a {
        Admission::Delivered => 1,
        Admission::Stale => 2,
        _ => 0, //~ A5
    }
}

pub fn route(q: QosClass, depth: usize) -> usize {
    match q {
        QosClass::Realtime => 0,
        other => depth, //~ A5
    }
}

pub fn evict_label(e: EvictPolicy) -> &'static str {
    match e {
        EvictPolicy::Affinity { .. } => "affinity",
        _ => "other", //~ A5
    }
}
