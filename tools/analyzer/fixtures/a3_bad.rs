//! A3 bad: an untimed wait with no annotation, and one whose
//! annotation names a loom model that does not exist.

pub fn unannotated(cv: &Condvar, mut g: Guard) -> Guard {
    loop {
        if g.ready {
            return g;
        }
        g = cv.wait(g); //~ A3
    }
}

pub fn names_missing_model(cv: &Condvar, g: Guard) -> Guard {
    // loom-verified: loom_model_that_does_not_exist
    cv.wait(g) //~ A3
}
