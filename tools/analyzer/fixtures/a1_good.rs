//! A1 good: benign std imports; sync only through the facade; the
//! banned paths appearing in strings and prose do not count.

use std::collections::{HashMap, VecDeque};
use std::io::Write as _;
use std::time::Duration;

pub fn stdlib_only() {
    let mut m: HashMap<u32, VecDeque<u32>> = HashMap::new();
    m.entry(1).or_default().push_back(2);
    let _d = Duration::from_millis(5);
    let _s = "std::sync is fine inside a string literal";
    // and std::thread in a comment is fine too
}
