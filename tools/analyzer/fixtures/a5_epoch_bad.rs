//! A5 bad (epoch custody): wildcard and catch-all arms in matches over
//! `EpochOutcome` swallow a future retirement variant and break the
//! per-version conservation `admitted == completed + failed + drained`.

pub fn book(o: EpochOutcome) -> u32 {
    match o {
        EpochOutcome::Completed => 1,
        _ => 0, //~ A5
    }
}

pub fn ledger_column(o: EpochOutcome) -> &'static str {
    match o {
        EpochOutcome::Completed => "completed",
        EpochOutcome::Failed => "failed",
        other => "drained", //~ A5
    }
}
