//! A1 bad: every known evasion of the old grep facade rule.

use std::{collections::HashMap, sync::Mutex}; //~ A1
use std::sync as s; //~ A1
use std::thread; //~ A1
use std as renamed; //~ A1

pub fn fully_qualified() {
    let _m = std::sync::Mutex::new(0u32); //~ A1
    let _t = std::thread::current(); //~ A1
    let _map: HashMap<u32, u32> = HashMap::new();
}
