#!/usr/bin/env python3
"""Python port of the QoS admission model (coordinator/wire.rs), used to
verify the arithmetic the Rust suite asserts and to derive the proxy
rows in EXPERIMENTS.md §Network QoS on a machine without a cargo
toolchain (same role as verify_tier_model.py for the weight tier).

Three parts:

1. `admit_at` boundary/monotonicity checks mirroring the
   `coordinator::wire` unit tests (integer arithmetic, no floats).
2. The zero-realtime-drop ceiling argument of
   `tests/net_qos.rs::qos_shedding_under_load_across_64_connections`,
   re-derived: with queue_depth 80, 4 producers and only 16 realtime
   frames in the run, a realtime push can never see a full injector.
3. The zero-service-limit proxy for the 64-connection scenario: all 592
   frames admitted in accept order before any service completes (the
   worst case for low classes — live runs drain during arrival, which
   only shifts drops downward, never reorders classes).
"""

QUEUE_DEPTH = 80
PRODUCERS = 4


def admit_at(cls, backlog, capacity):
    """Line-for-line port of QosClass::admit_at."""
    if cls == "realtime":
        return True
    if cls == "best-effort":
        return backlog * 4 < capacity * 3
    if cls == "batch":
        return backlog * 2 < capacity
    raise ValueError(cls)


def check(cond, msg):
    if not cond:
        raise SystemExit(f"FAIL: {msg}")
    print(f"  ok: {msg}")


def part1_boundaries():
    print("== admit_at boundaries and monotonicity ==")
    # the unit-test boundaries at capacity 64
    check(admit_at("batch", 31, 64) and not admit_at("batch", 32, 64),
          "batch admits at 31/64, refuses at 32/64 (1/2 boundary)")
    check(admit_at("best-effort", 47, 64) and not admit_at("best-effort", 48, 64),
          "best-effort admits at 47/64, refuses at 48/64 (3/4 boundary)")
    for cap in range(1, 257):
        for b in range(0, cap + 2):
            # realtime never refused by class policy
            assert admit_at("realtime", b, cap)
            # priority order, pointwise
            if admit_at("batch", b, cap):
                assert admit_at("best-effort", b, cap), (b, cap)
            # monotone: refusal never un-happens as backlog grows
            for cls in ("best-effort", "batch"):
                if not admit_at(cls, b, cap):
                    assert not admit_at(cls, b + 1, cap), (cls, b, cap)
    check(True, "priority order + monotonicity over caps 1..=256, all backlogs")


def part2_ceiling():
    print("== zero-realtime-drop ceiling (tests/net_qos.rs load test) ==")
    # best-effort admission floor: largest backlog still admitted
    be_floor = max(b for b in range(QUEUE_DEPTH) if admit_at("best-effort", b, QUEUE_DEPTH))
    bt_floor = max(b for b in range(QUEUE_DEPTH) if admit_at("batch", b, QUEUE_DEPTH))
    check(be_floor == 59, f"best-effort admits up to backlog {be_floor} (< 60)")
    check(bt_floor == 39, f"batch admits up to backlog {bt_floor} (< 40)")
    # non-realtime ceiling: one past the floor, plus one overshoot per
    # concurrent producer racing the same backlog read (the probe and
    # the push are not atomic — net.rs documents the race as shifting
    # borderline admission only)
    ceiling = be_floor + 1 + (PRODUCERS - 1)
    check(ceiling == 63, f"non-realtime backlog ceiling {ceiling}")
    rt_frames = 16
    worst = ceiling + rt_frames - 1
    check(worst < QUEUE_DEPTH,
          f"worst realtime push sees {worst} < {QUEUE_DEPTH} queued "
          "=> the hard cap cannot refuse realtime in any interleaving")


def part3_proxy():
    print("== zero-service-limit proxy (EXPERIMENTS.md §Network QoS) ==")
    # the load test's mix: conns 0..16 realtime x1, 16..40 best-effort
    # x12, 40..64 batch x12, drained whole-connection in accept order
    # (one 12-record stream fits one READ_CHUNK pump visit)
    offered = {"realtime": 0, "best-effort": 0, "batch": 0}
    delivered = {"realtime": 0, "best-effort": 0, "batch": 0}
    backlog = 0
    for conn in range(64):
        cls, n = (("realtime", 1) if conn < 16 else
                  ("best-effort", 12) if conn < 40 else ("batch", 12))
        for _ in range(n):
            offered[cls] += 1
            if admit_at(cls, backlog, QUEUE_DEPTH) and backlog < QUEUE_DEPTH:
                delivered[cls] += 1
                backlog += 1
    total = sum(offered.values())
    check(total == 592, "592 frames offered (16 + 24*12 + 24*12)")
    check(delivered["realtime"] == offered["realtime"] == 16,
          "realtime: 16/16 delivered, zero drops")
    check(delivered["best-effort"] == 44,
          "best-effort: 44/288 delivered in the zero-service limit "
          "(backlog 16 -> 60, then the 3/4 gate closes)")
    check(delivered["batch"] == 0,
          "batch: 0/288 delivered in the zero-service limit "
          "(the 1/2 gate is already closed at backlog 60)")
    print("  per-class proxy rows:")
    for cls in ("realtime", "best-effort", "batch"):
        d = delivered[cls]
        o = offered[cls]
        print(f"    {cls:<11} offered {o:>3}  delivered {d:>3}  "
              f"backpressure {o - d:>3}")


if __name__ == "__main__":
    part1_boundaries()
    part2_ceiling()
    part3_proxy()
    print("qos model verification OK")
