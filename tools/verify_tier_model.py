#!/usr/bin/env python3
"""Toolchain-free cross-check of the two-tier weight-memory cost model.

A line-for-line Python port of `rust/src/memory/tier.rs` (WeightTier +
the TierLedger custody transitions from `coordinator/audit.rs`), used
for two things on machines without a Rust toolchain:

  1. re-derive every arithmetic expectation asserted by the unit suite
     in `memory/tier.rs` (miss counts, stall seconds, byte totals), so
     the constants baked into those tests are independently checked;
  2. produce the deterministic cold-start stall numbers reported in
     BENCH_7.json: the `runtime_hotpath.rs` tier scenario (cnn5 /
     graph5 / msp430, fast tier = half the weight footprint, 24 frames
     in batch-8 rounds) run through the same cost arithmetic.

The port mirrors the Rust structure closely on purpose — BTreeMap
iteration becomes sorted-dict iteration so victim selection breaks ties
identically. Drift between this file and tier.rs is a bug in exactly
one of them; `cargo test --lib memory::tier::` is the ground truth once
a toolchain is present.

Run: python3 tools/verify_tier_model.py
"""

import json
import sys

# ------------------------------------------------------------- ledger


class TierLedger:
    """Custody transitions from coordinator/audit.rs::TierLedger."""

    def __init__(self):
        self.issued = 0
        self.completed = 0
        self.cancelled = 0
        self.inserted = 0
        self.evicted = 0

    def in_flight(self):
        f = self.issued - (self.completed + self.cancelled)
        assert f >= 0, "custody violation: retired more loads than issued"
        return f

    def resident(self):
        r = self.inserted - self.evicted
        assert r >= 0, "custody violation: evicted more than inserted"
        return r

    def issue(self, cached):
        self.issued += 1
        if cached:
            self.inserted += 1
        self.in_flight()

    def complete(self):
        self.completed += 1
        self.in_flight()

    def cancel(self):
        self.cancelled += 1
        self.evicted += 1
        self.in_flight()
        self.resident()

    def evict(self):
        self.evicted += 1
        self.resident()

    def reconcile(self, n_entries, n_in_flight):
        assert self.resident() == n_entries, (
            f"custody violation: ledger {self.resident()} resident, "
            f"tier holds {n_entries}"
        )
        assert self.in_flight() == n_in_flight, (
            f"custody violation: ledger {self.in_flight()} in flight, "
            f"tier tracks {n_in_flight}"
        )

    def close_check(self):
        assert self.issued == self.completed + self.cancelled, (
            f"custody violation: {self.issued} issued != "
            f"{self.completed} completed + {self.cancelled} cancelled"
        )


# --------------------------------------------------------------- tier

AFFINITY = "affinity"
LRU = "lru"


class Entry:
    __slots__ = (
        "bytes", "ready_at", "last_touch", "prefetched", "settled",
        "charged", "sharers",
    )

    def __init__(self, bytes_, ready_at, last_touch, prefetched, settled,
                 charged, sharers):
        self.bytes = bytes_
        self.ready_at = ready_at
        self.last_touch = last_touch
        self.prefetched = prefetched
        self.settled = settled
        self.charged = charged
        self.sharers = sharers


class Counters:
    FIELDS = (
        "hits", "misses", "prefetch_hits", "evictions", "prefetch_issued",
        "prefetch_cancelled", "stall_s", "bytes_loaded",
    )

    def __init__(self):
        for f in self.FIELDS:
            setattr(self, f, 0.0 if f == "stall_s" else 0)

    def as_dict(self):
        return {f: getattr(self, f) for f in self.FIELDS}


class WeightTier:
    """Port of memory/tier.rs::WeightTier. seq steps are
    (block, bytes, sharers) with block = (segment, group)."""

    def __init__(self, fast_bytes, prefetch, policy, read_bps):
        self.fast_bytes = fast_bytes
        self.prefetch = prefetch
        self.policy = policy
        self.read_bps = read_bps
        self.resident = {}  # block -> Entry; iterate sorted() = BTreeMap
        self.used = 0
        self.tick = 0
        self.now = 0.0
        self.dma_free = 0.0
        self.seq = []
        self.cursor = 0
        self.backlog_hint = 0
        self.c = Counters()
        self.ledger = TierLedger()

    def begin_round(self, seq, backlog_hint):
        self.seq = list(seq)
        self.cursor = 0
        self.backlog_hint = backlog_hint
        if self.prefetch:
            self.prefetch_round()
        self.reconcile()

    def upcoming_uses(self, b):
        ahead = sum(
            1 for s in self.seq[min(self.cursor, len(self.seq)):]
            if s[0] == b
        )
        nxt = (
            sum(1 for s in self.seq if s[0] == b)
            if self.backlog_hint > 0 else 0
        )
        return ahead + nxt

    def victim(self, require_unneeded):
        best = None
        for b in sorted(self.resident):
            e = self.resident[b]
            upcoming = self.upcoming_uses(b)
            if require_unneeded and upcoming > 0:
                continue
            if self.policy == AFFINITY:
                key = (upcoming, e.sharers, e.last_touch)
            else:
                key = (0, 0, e.last_touch)
            if best is None or (key, b) < best:
                best = (key, b)
        return best[1] if best else None

    def evict(self, b):
        e = self.resident.pop(b, None)
        if e is None:
            return
        self.used -= e.bytes
        self.c.evictions += 1
        if e.settled:
            self.ledger.evict()
        else:
            self.ledger.cancel()
            if e.prefetched:
                self.c.prefetch_cancelled += 1

    def make_room(self, bytes_, require_unneeded):
        if bytes_ > self.fast_bytes:
            return False
        while self.used + bytes_ > self.fast_bytes:
            v = self.victim(require_unneeded)
            if v is None:
                return False
            self.evict(v)
        return True

    def prefetch_round(self):
        seen = []
        for (block, bytes_, sharers) in list(self.seq):
            if block in seen or block in self.resident:
                continue
            seen.append(block)
            if not self.make_room(bytes_, True):
                continue
            start = self.now if self.now > self.dma_free else self.dma_free
            ready = start + bytes_ / self.read_bps
            self.dma_free = ready
            self.ledger.issue(True)
            self.c.prefetch_issued += 1
            self.c.bytes_loaded += bytes_
            self.resident[block] = Entry(
                bytes_, ready, 0, True, False, False, sharers
            )
            self.used += bytes_

    def advance_exec(self, secs):
        self.now += secs
        for e in self.resident.values():
            if not e.settled and e.ready_at <= self.now:
                e.settled = True
                self.ledger.complete()

    def touch(self, block, bytes_, sharers):
        self.tick += 1
        tail = self.seq[min(self.cursor, len(self.seq)):]
        for off, s in enumerate(tail):
            if s[0] == block:
                self.cursor = self.cursor + off + 1
                break
        stall = 0.0
        charge = 0
        e = self.resident.get(block)
        if e is not None:
            if e.ready_at > self.now:
                stall = e.ready_at - self.now
                self.now = e.ready_at
            if not e.settled:
                e.settled = True
                self.ledger.complete()
            if e.prefetched and e.last_touch == 0:
                self.c.prefetch_hits += 1
            if not e.charged:
                charge = e.bytes
                e.charged = True
            e.last_touch = self.tick
            self.c.hits += 1
            self.c.stall_s += stall
            self.reconcile()
            return stall, charge
        self.c.misses += 1
        start = self.now if self.now > self.dma_free else self.dma_free
        done = start + bytes_ / self.read_bps
        stall = done - self.now
        self.now = done
        self.dma_free = done
        charge = bytes_
        self.c.stall_s += stall
        self.c.bytes_loaded += bytes_
        cached = self.make_room(bytes_, False)
        self.ledger.issue(cached)
        self.ledger.complete()
        if cached:
            self.resident[block] = Entry(
                bytes_, done, self.tick, False, True, True, sharers
            )
            self.used += bytes_
        self.reconcile()
        return stall, charge

    def segment_view(self, nseg):
        view = [None] * nseg
        for (s, g) in sorted(self.resident):
            e = self.resident[(s, g)]
            if not e.settled or s >= nseg:
                continue
            if view[s] is not None and view[s][0] >= e.last_touch:
                continue
            view[s] = (e.last_touch, g)
        return [None if v is None else v[1] for v in view]

    def reconcile(self):
        in_flight = sum(1 for e in self.resident.values() if not e.settled)
        self.ledger.reconcile(len(self.resident), in_flight)

    def close_check(self):
        if self.dma_free > self.now:
            self.advance_exec(self.dma_free - self.now)
        self.reconcile()
        self.ledger.close_check()


# ------------------------------------------- unit-suite re-derivation

BPS = 1_000_000.0  # the unit suite's 1 MB/s: 1 byte = 1 us

CHECKS = []


def check(name):
    def deco(fn):
        CHECKS.append((name, fn))
        return fn
    return deco


def step(seg, grp, bytes_, sharers):
    return ((seg, grp), bytes_, sharers)


def run_seq(t, seq, backlog, exec_s):
    before = t.c.misses
    t.begin_round(seq, backlog)
    for (block, bytes_, sharers) in seq:
        t.touch(block, bytes_, sharers)
        t.advance_exec(exec_s)
    return t.c.misses - before


@check("affinity_beats_lru_on_load_count")
def _():
    a, b, c = step(0, 0, 1, 3), step(1, 0, 1, 1), step(2, 0, 1, 1)
    seq = [a, b, c, a]
    aff = WeightTier(2, False, AFFINITY, BPS)
    aff_misses = run_seq(aff, seq, 0, 0.0)
    lru = WeightTier(2, False, LRU, BPS)
    lru_misses = run_seq(lru, seq, 0, 0.0)
    assert aff_misses == 3, aff_misses
    assert lru_misses == 4, lru_misses
    assert aff.c.stall_s < lru.c.stall_s
    aff.close_check()
    lru.close_check()


@check("sharers_tiebreak_keeps_shared_block")
def _():
    t = WeightTier(2, False, AFFINITY, BPS)
    run_seq(t, [step(0, 0, 1, 4), step(1, 0, 1, 1), step(2, 0, 1, 1)], 0, 0.0)
    assert t.segment_view(3)[0] is not None
    assert t.segment_view(3)[1] is None
    t.close_check()


@check("capacity_zero_streams_everything")
def _():
    t = WeightTier(0, True, AFFINITY, BPS)
    seq = [step(0, 0, 10, 1), step(1, 0, 10, 1), step(0, 0, 10, 1)]
    misses = run_seq(t, seq, 1, 0.0)
    assert misses == 3 and t.c.hits == 0 and t.used == 0
    assert t.c.prefetch_issued == 0
    assert abs(t.c.stall_s - 30e-6) < 1e-12, t.c.stall_s
    t.close_check()


@check("thrash_terminates_and_balances")
def _():
    a, b = step(0, 0, 1, 1), step(0, 1, 1, 1)
    t = WeightTier(1, True, AFFINITY, BPS)
    run_seq(t, [a, b] * 50, 1, 0.0)
    assert t.c.hits + t.c.misses == 100
    assert t.c.evictions <= t.c.misses + t.c.prefetch_issued
    assert t.used <= 1
    t.close_check()


@check("prefetch_hides_stall_behind_compute")
def _():
    seq = [step(0, 0, 100, 1), step(1, 0, 100, 1), step(2, 0, 100, 1)]
    exec_s = 200e-6
    off = WeightTier(2**63, False, AFFINITY, BPS)
    run_seq(off, seq, 0, exec_s)
    on = WeightTier(2**63, True, AFFINITY, BPS)
    run_seq(on, seq, 0, exec_s)
    assert abs(off.c.stall_s - 300e-6) < 1e-12, off.c.stall_s
    assert abs(on.c.stall_s - 100e-6) < 1e-12, on.c.stall_s
    assert on.c.prefetch_hits == 3 and on.c.misses == 0
    off.close_check()
    on.close_check()


@check("unbounded_second_round_all_hits")
def _():
    seq = [step(0, 0, 10, 2), step(1, 0, 20, 1), step(2, 0, 30, 1)]
    t = WeightTier(2**63, False, AFFINITY, BPS)
    first = run_seq(t, seq, 0, 1e-3)
    stall_after_first = t.c.stall_s
    second = run_seq(t, seq, 0, 1e-3)
    assert first == 3 and second == 0
    assert t.c.stall_s == stall_after_first
    assert t.c.bytes_loaded == 60
    t.close_check()


@check("backlog_hint_makes_round_blocks_sticky")
def _():
    a, b = step(0, 0, 1, 2), step(1, 0, 1, 2)
    t = WeightTier(2, False, AFFINITY, BPS)
    run_seq(t, [a, b], 3, 0.0)
    misses = run_seq(t, [a, b], 0, 0.0)
    assert misses == 0, misses
    t.close_check()


@check("segment_view_tracks_settled_recency")
def _():
    t = WeightTier(2**63, True, AFFINITY, BPS)
    g0, g1 = step(0, 0, 100, 1), step(0, 1, 100, 1)
    t.begin_round([g0, g1], 0)
    assert t.segment_view(1) == [None]
    t.touch(g0[0], g0[1], g0[2])
    assert t.segment_view(1) == [0]
    t.touch(g1[0], g1[1], g1[2])
    assert t.segment_view(1) == [1]
    t.close_check()


@check("untouched_prefetch_balances_at_close")
def _():
    t = WeightTier(2**63, True, AFFINITY, BPS)
    t.begin_round([step(0, 0, 10, 1), step(1, 0, 10, 1)], 0)
    t.touch((0, 0), 10, 1)
    t.close_check()
    assert t.c.prefetch_issued == 2 and t.c.prefetch_hits == 1


# -------------------------------------- bench cold-start derivation
#
# The runtime_hotpath.rs tier scenario: cnn5 split at bounds [1,3,4]
# into 4 segments, graph5's partitions, msp430 rates, fast tier = half
# the weight footprint, 24 frames served in batch-8 rounds through the
# batched executor (run_round_batched): shared-trunk segments execute
# once per round per group (the batch-activation cache absorbs the
# rest), each executed segment touches its block then advances the
# clock by the batch's serial exec time.

MSP430_BPS = 4.0e6
MSP430_FREQ = 16e6
CYC_MAC, CYC_ELEM = 4.0, 2.0

# cnn5 per-layer (params, macs, out_elems); logits at ncls=2
CNN5 = [
    (3 * 3 * 1 * 8 + 8, 18_432, 8 * 8 * 8),
    (3 * 3 * 8 * 16 + 16, 73_728, 4 * 4 * 16),
    (256 * 64 + 64, 16_384, 64),
    (64 * 32 + 32, 2_048, 32),
    (32 * 2 + 2, 64, 2),
]
BOUNDS = [1, 3, 4]
# graph5 partitions: group_of[segment][task]
GROUPS = [
    [0, 0, 0, 0, 0],
    [0, 0, 0, 1, 1],
    [0, 1, 1, 2, 2],
    [0, 1, 2, 3, 4],
]


def segments():
    edges = [0] + BOUNDS + [len(CNN5)]
    out = []
    for s in range(len(edges) - 1):
        layers = CNN5[edges[s]:edges[s + 1]]
        bytes_ = sum(p for (p, _, _) in layers) * 4
        macs = sum(m for (_, m, _) in layers)
        elems = sum(e for (_, _, e) in layers)
        exec_s = (macs * CYC_MAC + elems * CYC_ELEM) / MSP430_FREQ
        out.append((bytes_, exec_s))
    return out


def bench_cold_start():
    segs = segments()
    nseg, ntasks = len(segs), 5
    footprint = sum(
        segs[s][0] * len(set(GROUPS[s])) for s in range(nseg)
    )
    cap = footprint // 2
    sharers = [
        [GROUPS[s].count(GROUPS[s][t]) for t in range(ntasks)]
        for s in range(nseg)
    ]
    n_frames, batch = 24, 8
    results = {}
    for prefetch in (False, True):
        t = WeightTier(cap, prefetch, AFFINITY, MSP430_BPS)
        remaining = n_frames
        while remaining > 0:
            m = min(batch, remaining)
            remaining -= m
            seq = [
                ((s, GROUPS[s][task]), segs[s][0], sharers[s][task])
                for task in range(ntasks)
                for s in range(nseg)
            ]
            t.begin_round(seq, remaining)
            bact = [None] * nseg  # batch-activation cache: group per seg
            for task in range(ntasks):
                for s in range(nseg):
                    g = GROUPS[s][task]
                    if bact[s] == g:
                        continue  # activation reused: no touch, no exec
                    t.touch((s, g), segs[s][0], sharers[s][task])
                    t.advance_exec(segs[s][1] * m)
                    bact[s] = g
        t.close_check()
        results["prefetch_on" if prefetch else "prefetch_off"] = t.c.as_dict()
    return {
        "footprint_bytes": footprint,
        "fast_tier_bytes": cap,
        "frames": n_frames,
        "batch": batch,
        **results,
    }


def main():
    failed = 0
    for name, fn in CHECKS:
        try:
            fn()
            print(f"  ok  {name}")
        except AssertionError as e:
            failed += 1
            print(f"FAIL  {name}: {e}")
    if failed:
        print(f"{failed} of {len(CHECKS)} tier-model checks FAILED")
        return 1
    print(f"all {len(CHECKS)} tier-model checks pass")
    bench = bench_cold_start()
    off = bench["prefetch_off"]
    on = bench["prefetch_on"]
    assert on["stall_s"] < off["stall_s"], (
        "prefetch must reduce visible stall below demand-only"
    )
    bench["stall_gain"] = off["stall_s"] / max(on["stall_s"], 1e-12)
    print(json.dumps(bench, indent=2))
    return 0


if __name__ == "__main__":
    sys.exit(main())
