#!/usr/bin/env bash
# Grep/awk static gates for the concurrency core — the documented
# NO-TOOLCHAIN FALLBACK. The gating lint lane is now the semantic
# analyzer (`cargo run -p pallas-analyzer`, rules A1-A5 — see
# CONCURRENCY.md §Static gates); ci.sh falls back to this script with a
# loud advisory only when cargo is unavailable. Kept honest because it
# still runs in the default lane: the rules below are the line-level
# approximations of A1-A3 (A4 guard-liveness and A5 custody
# exhaustiveness need token structure and have no grep equivalent).
#
# Three rules, all grep/awk — no extra toolchain:
#
#   R1  raw `std::sync` / `std::thread` anywhere in rust/src outside the
#       `sync/` facade. Concurrency that bypasses the facade is invisible
#       to the loom model checker (`./ci.sh --loom`), so it is banned at
#       the source level. Escape hatch: a `lint:allow(raw-sync)` comment
#       on the same line (for the rare type that loom cannot model —
#       document why).
#
#   R2  `.unwrap()` / `.expect(` on the serving hot path (the files that
#       run per-frame: shard/ingest/server/pool). A panic there kills a
#       worker and silently shrinks the pool; the sanctioned
#       alternatives are `?`, `lock_unpoisoned`/`wait_unpoisoned`, or an
#       explicit `lint:allow(panic)` comment within the 8 lines above,
#       stating why dying is correct. Test modules are exempt (the scan
#       stops at the first test-cfg marker).
#
#   R3  condvar waits must be loom-verified: every untimed `.wait(` /
#       `wait_unpoisoned(` call needs a `loom-verified:` comment within
#       the 8 lines above naming the loom test that proves its wake
#       protocol lost-wakeup-free (CONCURRENCY.md records the verdicts).
#       `wait_timeout` is exempt — a timeout is its own liveness floor.
set -euo pipefail
cd "$(dirname "$0")/.."
SRC=rust/src
fail=0

# ----------------------------------------------------------------- R1
# file:line:content hits, minus: the facade itself, comment-only lines,
# and explicit allows. Three patterns, matching the analyzer's A1:
#   plain paths        std::sync::… / std::thread::…
#   grouped imports    use std::{…, sync::…} / use std::{thread, …}
#   renamed std root   use std as s;  (aliasing the root defeats any
#                      later textual scan, so it is banned outright)
r1=$( { grep -rn -E 'std::(sync|thread)\b' "$SRC" --include='*.rs'; \
        grep -rn -E 'use[[:space:]]+(::)?std::\{[^}]*\b(sync|thread)\b' "$SRC" --include='*.rs'; \
        grep -rn -E 'use[[:space:]]+(::)?std[[:space:]]+as[[:space:]]' "$SRC" --include='*.rs'; } \
    | sort -u \
    | grep -v "^$SRC/sync/" \
    | grep -vE '^[^:]+:[0-9]+:[[:space:]]*//' \
    | grep -v 'lint:allow(raw-sync)' || true)
if [[ -n "$r1" ]]; then
    echo "LINT R1: raw std::sync/std::thread outside the sync facade"
    echo "         (route through crate::sync so loom can model it):"
    echo "$r1" | sed 's/^/  /'
    fail=1
fi

# ----------------------------------------------------------------- R2
hot_files=(
    "$SRC/coordinator/shard.rs"
    "$SRC/coordinator/ingest.rs"
    "$SRC/coordinator/server.rs"
    "$SRC/coordinator/net.rs"
    "$SRC/coordinator/wire.rs"
    "$SRC/coordinator/executor.rs"
    "$SRC/coordinator/audit.rs"
    "$SRC/coordinator/registry.rs"
    "$SRC/coordinator/replan.rs"
    "$SRC/exec/pool.rs"
    "$SRC/memory/tier.rs"
)
for f in "${hot_files[@]}"; do
    [[ -f "$f" ]] || continue
    hits=$(awk '
        /#\[cfg\(.*test/ || /^mod tests/ || /^[[:space:]]*mod (tests|loom_tests)/ { exit }
        { win[NR % 9] = $0 }
        /\.unwrap\(\)/ || /\.expect\(/ {
            if ($0 ~ /^[[:space:]]*\/\//) next
            ok = 0
            for (i = 0; i < 9; i++) if (win[i] ~ /lint:allow\(panic\)/) ok = 1
            if (!ok) printf "  %s:%d:%s\n", FILENAME, NR, $0
        }
    ' "$f")
    if [[ -n "$hits" ]]; then
        echo "LINT R2: unwrap()/expect() on the serving hot path"
        echo "         (use ?, lock_unpoisoned, or lint:allow(panic) + why):"
        echo "$hits"
        fail=1
    fi
done

# ----------------------------------------------------------------- R3
r3_files=$(grep -rl -E '\.wait\(|wait_unpoisoned\(' "$SRC" --include='*.rs' \
    | grep -v "^$SRC/sync/" || true)
for f in $r3_files; do
    hits=$(awk '
        { win[NR % 9] = $0 }
        /\.wait\(|wait_unpoisoned\(/ {
            if ($0 ~ /^[[:space:]]*\/\//) next
            if ($0 ~ /wait_timeout/) next
            ok = 0
            for (i = 0; i < 9; i++) if (win[i] ~ /loom-verified:/) ok = 1
            if (!ok) printf "  %s:%d:%s\n", FILENAME, NR, $0
        }
    ' "$f")
    if [[ -n "$hits" ]]; then
        echo "LINT R3: condvar wait without a loom-verified annotation"
        echo "         (name the loom test proving the wake protocol):"
        echo "$hits"
        fail=1
    fi
done

if [[ "$fail" != 0 ]]; then
    echo "custom lint FAILED"
    exit 1
fi
echo "custom lint clean (R1 facade, R2 hot-path panics, R3 wait annotations)"
