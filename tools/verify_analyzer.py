#!/usr/bin/env python3
"""Cross-check port of pallas-analyzer (tools/analyzer) in Python.

The analyzer itself is dependency-free Rust and is exercised by its own
`cargo test -p pallas-analyzer` fixture battery. On boxes without a
Rust toolchain, this script is the executable mirror: a line-for-line
port of the lexer, the structural model, and the five rules (A1-A5),
run against the same fixtures (`tools/analyzer/fixtures/*.rs`, with
`//~ RULE` markers) and the real tree (`rust/src`). If the port and
the Rust source ever disagree, one of them has a bug — same
methodology as tools/verify_qos_model.py / verify_tier_model.py.

Usage:  python3 tools/verify_analyzer.py [REPO_ROOT]
Exit 0: unit checks pass, every fixture matches its markers, tree clean.
"""

import os
import sys

# ===================================================================
# lexer.rs port
# ===================================================================

IDENT, LIFETIME, INT, FLOAT, STR, CHAR, COMMENT, PUNCT = range(8)


class Tok:
    __slots__ = ("kind", "text", "line", "end_line", "pos")

    def __init__(self, kind, text, line, end_line, pos):
        self.kind = kind
        self.text = text
        self.line = line
        self.end_line = end_line
        self.pos = pos

    def is_punct(self, c):
        return self.kind == PUNCT and self.text == c

    def is_ident(self, s):
        return self.kind == IDENT and self.text == s

    def is_plain_int(self):
        return self.kind == INT


def ident_start(c):
    return c.isalpha() or c == "_"


def ident_cont(c):
    return c.isalnum() or c == "_"


def lex_str_body(cs, i, line):
    n = len(cs)
    i += 1
    while i < n:
        if cs[i] == "\\":
            if i + 1 < n and cs[i + 1] == "\n":
                line += 1
            i += 2
        elif cs[i] == '"':
            return i + 1, line
        elif cs[i] == "\n":
            line += 1
            i += 1
        else:
            i += 1
    return i, line


def lex_char_body(cs, i, line):
    n = len(cs)
    i += 1
    while i < n:
        if cs[i] == "\\":
            if i + 1 < n and cs[i + 1] == "\n":
                line += 1
            i += 2
        elif cs[i] == "'":
            return i + 1, line
        elif cs[i] == "\n":
            line += 1
            i += 1
        else:
            i += 1
    return i, line


def lex(src):
    cs = list(src)
    n = len(cs)
    toks = []
    i = 0
    line = 1
    while i < n:
        c = cs[i]
        if c == "\n":
            line += 1
            i += 1
            continue
        if c.isspace():
            i += 1
            continue
        # comments
        if c == "/" and i + 1 < n and cs[i + 1] == "/":
            start = i
            while i < n and cs[i] != "\n":
                i += 1
            toks.append(Tok(COMMENT, "".join(cs[start:i]), line, line, start))
            continue
        if c == "/" and i + 1 < n and cs[i + 1] == "*":
            start, start_line = i, line
            depth = 1
            i += 2
            while i < n and depth > 0:
                if cs[i] == "\n":
                    line += 1
                    i += 1
                elif cs[i] == "/" and i + 1 < n and cs[i + 1] == "*":
                    depth += 1
                    i += 2
                elif cs[i] == "*" and i + 1 < n and cs[i + 1] == "/":
                    depth -= 1
                    i += 2
                else:
                    i += 1
            toks.append(Tok(COMMENT, "".join(cs[start:i]), start_line, line, start))
            continue
        # raw strings / byte strings / r#idents
        if c in ("r", "b"):
            j = i
            is_raw = False
            is_byte_char = False
            if cs[j] == "b":
                j += 1
                if j < n and cs[j] == "r":
                    is_raw = True
                    j += 1
                elif j < n and cs[j] == "'":
                    is_byte_char = True
            else:
                j += 1
                is_raw = True
            if is_byte_char:
                start, start_line = i, line
                i, line = lex_char_body(cs, j, line)
                toks.append(Tok(CHAR, "".join(cs[start:i]), start_line, line, start))
                continue
            hashes = 0
            k = j
            while is_raw and k < n and cs[k] == "#":
                hashes += 1
                k += 1
            raw_string = is_raw and k < n and cs[k] == '"'
            plain_string = (not is_raw) and j < n and cs[j] == '"' and cs[i] == "b"
            if raw_string:
                start, start_line = i, line
                i = k + 1
                while i < n:
                    if cs[i] == "\n":
                        line += 1
                        i += 1
                        continue
                    if cs[i] == '"':
                        h = 0
                        while h < hashes and i + 1 + h < n and cs[i + 1 + h] == "#":
                            h += 1
                        if h == hashes:
                            i += 1 + hashes
                            break
                    i += 1
                toks.append(Tok(STR, 'r"…"', start_line, line, start))
                continue
            if plain_string:
                start, start_line = i, line
                i, line = lex_str_body(cs, j, line)
                toks.append(Tok(STR, "".join(cs[start:min(i, n)]), start_line, line, start))
                continue
            if is_raw and hashes == 1 and k < n and ident_start(cs[k]):
                start = i
                e = k
                while e < n and ident_cont(cs[e]):
                    e += 1
                toks.append(Tok(IDENT, "".join(cs[k:e]), line, line, start))
                i = e
                continue
            # plain identifier starting with r/b — fall through
        # strings
        if c == '"':
            start, start_line = i, line
            i, line = lex_str_body(cs, i, line)
            toks.append(Tok(STR, "".join(cs[start:min(i, n)]), start_line, line, start))
            continue
        # char literal vs lifetime
        if c == "'":
            if i + 1 < n and ident_start(cs[i + 1]) and cs[i + 1] != "\\":
                e = i + 1
                while e < n and ident_cont(cs[e]):
                    e += 1
                if e < n and cs[e] == "'" and e > i + 1:
                    toks.append(Tok(CHAR, "".join(cs[i:e + 1]), line, line, i))
                    i = e + 1
                    continue
                toks.append(Tok(LIFETIME, "".join(cs[i:e]), line, line, i))
                i = e
                continue
            start, start_line = i, line
            i, line = lex_char_body(cs, i, line)
            toks.append(Tok(CHAR, "".join(cs[start:min(i, n)]), start_line, line, start))
            continue
        # numbers
        if c.isdigit():
            start = i
            saw_dot = False
            while i < n and ident_cont(cs[i]):
                i += 1
            if i + 1 < n and cs[i] == "." and cs[i + 1].isdigit():
                saw_dot = True
                i += 1
                while i < n and ident_cont(cs[i]):
                    i += 1
            if (
                i < n
                and cs[i] in ("+", "-")
                and i > start
                and cs[i - 1] in ("e", "E")
                and i + 1 < n
                and cs[i + 1].isdigit()
            ):
                saw_dot = True
                i += 1
                while i < n and ident_cont(cs[i]):
                    i += 1
            text = "".join(cs[start:i])
            kind = FLOAT if (saw_dot or "." in text) else INT
            toks.append(Tok(kind, text, line, line, start))
            continue
        # identifiers
        if ident_start(c):
            start = i
            while i < n and ident_cont(cs[i]):
                i += 1
            toks.append(Tok(IDENT, "".join(cs[start:i]), line, line, start))
            continue
        toks.append(Tok(PUNCT, c, line, line, i))
        i += 1
    return toks


# ===================================================================
# model.rs port
# ===================================================================


class FileModel:
    def __init__(self, rel, src):
        self.rel = rel
        self.toks = lex(src)
        nlines = len(src.splitlines()) + 2
        self.line_is_code = [False] * (nlines + 1)
        self.line_has_comment = [False] * (nlines + 1)
        self.line_comment = [""] * (nlines + 1)
        self.code = [i for i, t in enumerate(self.toks) if t.kind != COMMENT]
        for t in self.toks:
            for l in range(t.line, min(t.end_line, nlines) + 1):
                if t.kind == COMMENT:
                    self.line_has_comment[l] = True
                else:
                    self.line_is_code[l] = True
            if t.kind == COMMENT:
                self.line_comment[t.line] += t.text + " "
        self.test_line = [False] * (nlines + 1)
        self._mark_test_regions()

    def tok(self, code_idx):
        return self.toks[self.code[code_idx]]

    def ncode(self):
        return len(self.code)

    def glued(self, a, b):
        return self.tok(b).pos == self.tok(a).pos + 1

    def is_path_sep(self, i):
        return (
            i + 1 < self.ncode()
            and self.tok(i).is_punct(":")
            and self.tok(i + 1).is_punct(":")
            and self.glued(i, i + 1)
        )

    def parse_attr(self, i):
        j = i + 2
        depth = 1
        paren_stack = []
        pending = None
        is_test = False
        while j < self.ncode() and depth > 0:
            t = self.tok(j)
            if t.is_punct("["):
                depth += 1
            elif t.is_punct("]"):
                depth -= 1
            elif t.is_punct("("):
                paren_stack.append(pending if pending is not None else "")
                pending = None
            elif t.is_punct(")"):
                if paren_stack:
                    paren_stack.pop()
            elif t.kind == IDENT:
                if t.text == "test" and "not" not in paren_stack:
                    is_test = True
                pending = t.text
            j += 1
        return j, is_test

    def item_end(self, i):
        j = i
        depth = 0
        while j < self.ncode():
            t = self.tok(j)
            if t.is_punct("(") or t.is_punct("["):
                depth += 1
            elif t.is_punct(")") or t.is_punct("]"):
                depth -= 1
            elif t.is_punct("{"):
                if depth == 0:
                    b = 1
                    k = j + 1
                    while k < self.ncode() and b > 0:
                        if self.tok(k).is_punct("{"):
                            b += 1
                        elif self.tok(k).is_punct("}"):
                            b -= 1
                        k += 1
                    return max(k - 1, 0)
                depth += 1
            elif t.is_punct("}"):
                depth -= 1
            elif t.is_punct(";") and depth == 0:
                return j
            j += 1
        return max(self.ncode() - 1, 0)

    def _mark_span_test(self, a, b):
        for l in range(a, min(b, len(self.test_line) - 1) + 1):
            self.test_line[l] = True

    def _mark_test_regions(self):
        k = 0
        pending_test = False
        pending_line = 0
        while k < self.ncode():
            t = self.tok(k)
            if t.is_punct("#") and k + 1 < self.ncode() and self.tok(k + 1).is_punct("["):
                after, is_test = self.parse_attr(k)
                if is_test and not pending_test:
                    pending_test = True
                    pending_line = t.line
                k = after
                continue
            if pending_test:
                end = self.item_end(k)
                self._mark_span_test(pending_line, self.tok(end).end_line)
                pending_test = False
                k = end + 1
                continue
            if (
                t.is_ident("mod")
                and k + 1 < self.ncode()
                and self.tok(k + 1).kind == IDENT
                and self.tok(k + 1).text in ("tests", "loom_tests")
            ):
                end = self.item_end(k)
                self._mark_span_test(t.line, self.tok(end).end_line)
                k = end + 1
                continue
            k += 1

    def stmt_first(self, code_idx):
        depth = 0
        j = code_idx
        while j > 0:
            t = self.tok(j - 1)
            if t.is_punct(")") or t.is_punct("]") or t.is_punct("}"):
                depth += 1
            elif t.is_punct("(") or t.is_punct("[") or t.is_punct("{"):
                if depth == 0:
                    return j
                depth -= 1
            elif t.is_punct(";") and depth == 0:
                return j
            elif (
                t.is_punct(">")
                and depth == 0
                and j >= 2
                and self.tok(j - 2).is_punct("=")
                and self.glued(j - 2, j - 1)
            ):
                return j
            j -= 1
        return 0

    def attached_comments(self, code_idx):
        first = self.stmt_first(code_idx)
        start_line = self.tok(first).line
        end_line = self.tok(code_idx).line
        text = ""
        l = start_line - 1
        while l >= 1 and not self.line_is_code[l] and self.line_has_comment[l]:
            text += self.line_comment[l]
            if l == 1:
                break
            l -= 1
        for l in range(start_line, min(end_line, len(self.line_comment) - 1) + 1):
            text += self.line_comment[l]
        return text

    def allowed(self, code_idx, annotation):
        return annotation in self.attached_comments(code_idx)


# ===================================================================
# config.rs port
# ===================================================================

HOT_FILES = [
    "coordinator/shard.rs",
    "coordinator/ingest.rs",
    "coordinator/server.rs",
    "coordinator/net.rs",
    "coordinator/wire.rs",
    "coordinator/executor.rs",
    "coordinator/audit.rs",
    "coordinator/registry.rs",
    "coordinator/replan.rs",
    "exec/pool.rs",
    "memory/tier.rs",
]
CUSTODY_ENUMS = [
    "Admission",
    "QosClass",
    "EvictPolicy",
    "SegmentAction",
    "EpochOutcome",
]


class Config:
    def __init__(self, facade_prefix, hot_files, custody_enums):
        self.facade_prefix = facade_prefix
        self.hot_files = hot_files
        self.custody_enums = custody_enums

    @staticmethod
    def tree():
        return Config("sync/", list(HOT_FILES), list(CUSTODY_ENUMS))

    @staticmethod
    def fixtures(rel):
        return Config("sync/", [rel], list(CUSTODY_ENUMS))

    def is_facade(self, rel):
        return rel.startswith(self.facade_prefix)

    def is_hot(self, rel):
        return rel in self.hot_files


# ===================================================================
# rules.rs port
# ===================================================================


class Finding:
    def __init__(self, file, line, rule, msg):
        self.file = file
        self.line = line
        self.rule = rule
        self.msg = msg

    def render(self):
        return "%s:%d: %s: %s" % (self.file, self.line, self.rule, self.msg)


def scan_loom_fns(models):
    loom_fns = set()
    for m in models:
        for i in range(max(m.ncode() - 1, 0)):
            if m.tok(i).is_ident("fn"):
                nx = m.tok(i + 1)
                if nx.kind == IDENT and nx.text.startswith("loom_"):
                    loom_fns.add(nx.text)
    return loom_fns


def analyze_file(m, cfg, loom_fns):
    out = []
    if cfg.is_facade(m.rel):
        return out
    rule_a1(m, out)
    if cfg.is_hot(m.rel):
        rule_a2(m, out)
    rule_a3(m, loom_fns, out)
    rule_a4(m, out)
    rule_a5(m, cfg, out)
    out.sort(key=lambda f: (f.line, f.rule))
    return out


def push(out, m, line, rule, msg):
    out.append(Finding(m.rel, line, rule, msg))


# --------------------------------------------------------------- A1


def parse_use_tree(m, i, prefix, leaves):
    segs = list(prefix)
    while i < m.ncode():
        t = m.tok(i)
        if t.is_punct(":") and m.is_path_sep(i):
            i += 2
            continue
        if t.is_punct("{"):
            i += 1
            while True:
                if i >= m.ncode():
                    return i
                if m.tok(i).is_punct("}"):
                    return i + 1
                i = parse_use_tree(m, i, segs, leaves)
                if i < m.ncode() and m.tok(i).is_punct(","):
                    i += 1
                    continue
                if i < m.ncode() and m.tok(i).is_punct("}"):
                    return i + 1
                return i
        if t.is_punct("*"):
            segs.append("*")
            leaves.append((segs, None, i))
            return i + 1
        if t.is_ident("as"):
            alias = None
            if i + 1 < m.ncode() and m.tok(i + 1).kind == IDENT:
                alias = m.tok(i + 1).text
            leaves.append((segs, alias, i))
            return i + 2
        if t.kind == IDENT:
            if t.text != "self":
                segs.append(t.text)
            i += 1
            continue
        if segs and segs != list(prefix):
            leaves.append((segs, None, max(i - 1, 0)))
        elif segs == list(prefix) and prefix:
            leaves.append((segs, None, max(i - 1, 0)))
        return i
    return i


def rule_a1(m, out):
    use_spans = []
    k = 0
    while k < m.ncode():
        if m.tok(k).is_ident("use"):
            start = k
            leaves = []
            i = parse_use_tree(m, k + 1, [], leaves)
            while i < m.ncode() and not m.tok(i).is_punct(";"):
                i += 1
            use_spans.append((start, i))
            for segs, alias, at in leaves:
                banned = (
                    len(segs) >= 2 and segs[0] == "std" and segs[1] in ("sync", "thread", "*")
                ) or (len(segs) == 1 and segs[0] == "std" and alias is not None)
                if banned and not m.allowed(start, "lint:allow(raw-sync)"):
                    path = "::".join(segs)
                    ali = " (as `%s`)" % alias if alias is not None else ""
                    push(
                        out,
                        m,
                        m.tok(at).line,
                        "A1",
                        "import resolves to `%s`%s outside the sync facade — "
                        "route through crate::sync so loom can model it "
                        "(lint:allow(raw-sync) + why, if loom cannot)" % (path, ali),
                    )
            k = i + 1
            continue
        k += 1
    in_use = lambda i: any(a <= i <= b for a, b in use_spans)
    for i in range(max(m.ncode() - 3, 0)):
        t = m.tok(i)
        if (
            t.is_ident("std")
            and m.is_path_sep(i + 1)
            and m.tok(i + 3).kind == IDENT
            and m.tok(i + 3).text in ("sync", "thread")
            and not in_use(i)
            and not m.allowed(i, "lint:allow(raw-sync)")
        ):
            push(
                out,
                m,
                t.line,
                "A1",
                "fully-qualified `std::%s` path outside the sync facade — "
                "route through crate::sync so loom can model it" % m.tok(i + 3).text,
            )


# --------------------------------------------------------------- A2


def rule_a2(m, out):
    ALLOW = "lint:allow(panic)"
    for i in range(m.ncode()):
        t = m.tok(i)
        if m.test_line[min(t.line, len(m.test_line) - 1)]:
            continue
        prev = m.tok(i - 1) if i > 0 else None
        nxt = m.tok(i + 1) if i + 1 < m.ncode() else None
        if (
            (t.is_ident("unwrap") or t.is_ident("expect"))
            and prev is not None
            and prev.is_punct(".")
            and nxt is not None
            and nxt.is_punct("(")
            and not m.allowed(i, ALLOW)
        ):
            push(
                out,
                m,
                t.line,
                "A2",
                ".%s() on the serving hot path — a panic here kills a worker and "
                "silently shrinks the pool; use `?`, lock_unpoisoned, or "
                "lint:allow(panic) + why dying is correct" % t.text,
            )
        if (
            t.is_ident("panic")
            and nxt is not None
            and nxt.is_punct("!")
            and not m.allowed(i, ALLOW)
        ):
            push(
                out,
                m,
                t.line,
                "A2",
                "panic! on the serving hot path — return an error or annotate "
                "lint:allow(panic) + why dying is correct",
            )
        if (
            t.is_punct("[")
            and prev is not None
            and (prev.kind == IDENT or prev.is_punct(")") or prev.is_punct("]"))
            and nxt is not None
            and nxt.is_plain_int()
            and i + 2 < m.ncode()
            and m.tok(i + 2).is_punct("]")
            and not m.allowed(i, ALLOW)
        ):
            push(
                out,
                m,
                t.line,
                "A2",
                "indexing with integer literal `[%s]` on the serving hot path — "
                "out-of-bounds panics kill the worker; use .get()/.first() or "
                "lint:allow(panic) + the invariant that bounds it" % m.tok(i + 1).text,
            )


# --------------------------------------------------------------- A3


def loom_names(text):
    names = []
    i = 0
    while i < len(text):
        if text.startswith("loom_", i):
            j = i
            while j < len(text) and (text[j].isalnum() and text[j].isascii() or text[j] == "_"):
                j += 1
            name = text[i:j]
            if name not in names:
                names.append(name)
            i = j
        else:
            i += 1
    return names


def rule_a3(m, loom_fns, out):
    for i in range(m.ncode()):
        t = m.tok(i)
        dotted_wait = (
            t.is_ident("wait")
            and i > 0
            and m.tok(i - 1).is_punct(".")
            and i + 1 < m.ncode()
            and m.tok(i + 1).is_punct("(")
        )
        facade_wait = (
            t.is_ident("wait_unpoisoned")
            and i + 1 < m.ncode()
            and m.tok(i + 1).is_punct("(")
            and not (i > 0 and m.tok(i - 1).is_ident("fn"))
        )
        if not dotted_wait and not facade_wait:
            continue
        ann = m.attached_comments(i)
        if "loom-verified:" not in ann:
            push(
                out,
                m,
                t.line,
                "A3",
                "untimed condvar wait without a `loom-verified:` annotation naming "
                "the loom model that proves its wake protocol lost-wakeup-free "
                "(wait_timeout is exempt — a timeout is its own liveness floor)",
            )
            continue
        names = loom_names(ann)
        if not any(n in loom_fns for n in names):
            push(
                out,
                m,
                t.line,
                "A3",
                "`loom-verified:` annotation names no loom model that exists in "
                "the crate (named: %s; known models: %s)"
                % (", ".join(names) if names else "none", ", ".join(sorted(loom_fns))),
            )


# --------------------------------------------------------------- A4

GUARD_ALLOW = "lint:allow(guard-across-blocking)"


def guard_binding(m, let_idx):
    j = let_idx + 1
    if j < m.ncode() and m.tok(j).is_ident("mut"):
        j += 1
    if j >= m.ncode() or m.tok(j).kind != IDENT:
        return None
    name = m.tok(j).text
    line = m.tok(j).line
    j += 1
    depth = 0
    while j < m.ncode():
        t = m.tok(j)
        if t.is_punct("(") or t.is_punct("[") or t.is_punct("{"):
            depth += 1
        elif t.is_punct(")") or t.is_punct("]") or t.is_punct("}"):
            depth -= 1
        elif t.is_punct(";") and depth <= 0:
            return None
        elif t.is_punct("=") and depth == 0:
            break
        j += 1
    depth = 0
    k = j + 1
    while k < m.ncode():
        t = m.tok(k)
        if t.is_punct("{"):
            b = 1
            k += 1
            while k < m.ncode() and b > 0:
                if m.tok(k).is_punct("{"):
                    b += 1
                elif m.tok(k).is_punct("}"):
                    b -= 1
                k += 1
            continue
        if t.is_punct("(") or t.is_punct("["):
            depth += 1
        elif t.is_punct(")") or t.is_punct("]") or t.is_punct("}"):
            if depth == 0:
                break
            depth -= 1
        elif t.is_punct(";") and depth == 0:
            break
        elif t.is_ident("lock_unpoisoned") or (
            t.is_ident("lock") and k > 0 and m.tok(k - 1).is_punct(".")
        ):
            return (name, line)
        k += 1
    return None


def blocking_site(m, i):
    t = m.tok(i)
    if not (i + 1 < m.ncode() and m.tok(i + 1).is_punct("(")):
        return None
    prev_dot = i > 0 and m.tok(i - 1).is_punct(".")
    prev_fn = i > 0 and m.tok(i - 1).is_ident("fn")
    if prev_fn:
        return None
    wait_family = (prev_dot and t.text in ("wait", "wait_timeout") and t.kind == IDENT) or t.is_ident(
        "wait_unpoisoned"
    )
    sleep_family = (not prev_dot) and t.kind == IDENT and t.text in ("sleep", "busy_wait")
    chan_family = prev_dot and t.kind == IDENT and t.text in ("join", "send", "recv", "recv_timeout")
    if not (wait_family or sleep_family or chan_family):
        return None
    consumed = []
    if wait_family:
        depth = 0
        k = i + 1
        while k < m.ncode():
            a = m.tok(k)
            if a.is_punct("("):
                depth += 1
            elif a.is_punct(")"):
                depth -= 1
                if depth == 0:
                    break
            elif a.kind == IDENT:
                consumed.append(a.text)
            k += 1
    return (".%s(" % t.text, consumed)


def rule_a4(m, out):
    guards = []  # (name, depth, line)
    brace = 0
    i = 0
    while i < m.ncode():
        t = m.tok(i)
        on_test_line = m.test_line[min(t.line, len(m.test_line) - 1)]
        if t.is_punct("{"):
            brace += 1
        elif t.is_punct("}"):
            brace -= 1
            guards = [g for g in guards if g[1] <= brace]
        elif (
            t.is_ident("drop")
            and i + 3 < m.ncode()
            and m.tok(i + 1).is_punct("(")
            and m.tok(i + 2).kind == IDENT
            and m.tok(i + 3).is_punct(")")
        ):
            name = m.tok(i + 2).text
            guards = [g for g in guards if g[0] != name]
        elif t.is_ident("let") and not on_test_line:
            gb = guard_binding(m, i)
            if gb is not None:
                guards.append((gb[0], brace, gb[1]))
        elif not on_test_line:
            site = blocking_site(m, i)
            if site is not None:
                kind, consumed = site
                offenders = [g for g in guards if g[0] not in consumed]
                if offenders and not m.allowed(i, GUARD_ALLOW):
                    held = ", ".join("`%s` (bound line %d)" % (g[0], g[2]) for g in offenders)
                    push(
                        out,
                        m,
                        t.line,
                        "A4",
                        "lock guard %s held across blocking call `%s` — every thread "
                        "contending that mutex now waits on this call too; drop the "
                        "guard first, or annotate lint:allow(guard-across-blocking) "
                        "+ why it cannot deadlock" % (held, kind),
                    )
        i += 1


# --------------------------------------------------------------- A5


def split_arms(m, open_idx):
    arms = []
    i = open_idx + 1
    pat = []
    depth = 0
    in_body = False
    while i < m.ncode():
        t = m.tok(i)
        if t.is_punct("{") or t.is_punct("(") or t.is_punct("["):
            depth += 1
            if in_body and t.is_punct("{") and depth == 1:
                b = 1
                k = i + 1
                while k < m.ncode() and b > 0:
                    if m.tok(k).is_punct("{"):
                        b += 1
                    elif m.tok(k).is_punct("}"):
                        b -= 1
                    k += 1
                i = k
                depth -= 1
                in_body = False
                arms.append(pat)
                pat = []
                if i < m.ncode() and m.tok(i).is_punct(","):
                    i += 1
                continue
        elif t.is_punct("}") or t.is_punct(")") or t.is_punct("]"):
            if depth == 0 and t.is_punct("}"):
                if pat:
                    arms.append(pat)
                    pat = []
                break
            depth -= 1
        elif (
            depth == 0
            and t.is_punct("=")
            and i + 1 < m.ncode()
            and m.tok(i + 1).is_punct(">")
            and m.tok(i + 1).pos == t.pos + 1
        ):
            in_body = True
            i += 2
            continue
        elif depth == 0 and t.is_punct(",") and in_body:
            arms.append(pat)
            pat = []
            in_body = False
            i += 1
            continue
        if not in_body:
            pat.append(i)
        i += 1
    return arms


def rule_a5(m, cfg, out):
    ALLOW = "lint:allow(custody-wildcard)"
    for i in range(m.ncode()):
        if not m.tok(i).is_ident("match"):
            continue
        j = i + 1
        depth = 0
        while j < m.ncode():
            t = m.tok(j)
            if t.is_punct("(") or t.is_punct("["):
                depth += 1
            elif t.is_punct(")") or t.is_punct("]"):
                depth -= 1
            elif t.is_punct("{") and depth == 0:
                break
            j += 1
        if j >= m.ncode():
            continue
        arms = split_arms(m, j)
        custody = any(
            m.tok(p).kind == IDENT
            and m.tok(p).text in cfg.custody_enums
            and m.is_path_sep(p + 1)
            for a in arms
            for p in a
        )
        if not custody:
            continue
        for a in arms:
            core = []
            for p in a:
                if m.tok(p).is_ident("if"):
                    break
                core.append(p)
            if len(core) != 1:
                continue
            p = core[0]
            t = m.tok(p)
            is_wild = t.is_ident("_")
            is_binding = (
                not is_wild
                and t.kind == IDENT
                and len(t.text) > 0
                and (t.text[0].islower() or t.text[0] == "_")
                and t.text not in ("true", "false")
            )
            if (is_wild or is_binding) and not m.allowed(p, ALLOW):
                what = (
                    "wildcard `_` arm" if is_wild else "catch-all binding `%s` arm" % t.text
                )
                push(
                    out,
                    m,
                    t.line,
                    "A5",
                    "%s in a match over a custody enum — a new variant would be "
                    "silently absorbed instead of forcing this accounting site to "
                    "be revisited; enumerate every variant "
                    "(lint:allow(custody-wildcard) + why, if the arm is genuinely "
                    "variant-independent)" % what,
                )


# ===================================================================
# lib.rs port: analyze_sources / analyze_tree
# ===================================================================


def analyze_sources(sources, cfg):
    models = [FileModel(rel, src) for rel, src in sources]
    loom_fns = scan_loom_fns(models)
    out = []
    for m in models:
        out.extend(analyze_file(m, cfg, loom_fns))
    out.sort(key=lambda f: (f.file, f.line, f.rule))
    return out


def analyze_tree(root):
    src_root = os.path.join(root, "rust", "src")
    files = []
    for dirpath, _dirnames, filenames in os.walk(src_root):
        for fn in filenames:
            if fn.endswith(".rs"):
                rel = os.path.relpath(os.path.join(dirpath, fn), src_root).replace(os.sep, "/")
                files.append(rel)
    files.sort()
    sources = []
    for rel in files:
        with open(os.path.join(src_root, rel), encoding="utf-8") as f:
            sources.append((rel, f.read()))
    findings = analyze_sources(sources, Config.tree())
    for f in findings:
        f.file = "rust/src/" + f.file
    return findings


# ===================================================================
# verification driver
# ===================================================================

FAILURES = []


def check(name, cond, detail=""):
    if cond:
        print("  ok   %s" % name)
    else:
        print("  FAIL %s %s" % (name, detail))
        FAILURES.append(name)


def unit_checks():
    print("[1/3] unit checks (mirroring the Rust crate's #[cfg(test)] suites)")
    toks = lex('let s = "std::sync"; // std::thread')
    check(
        "lexer: strings/comments are not idents",
        not any(t.kind == IDENT and t.text in ("sync", "thread") for t in toks),
    )
    toks = lex('let x = r#"a "quoted" std::sync"# ; let y = 1;')
    idents = [t.text for t in toks if t.kind == IDENT]
    check("lexer: raw strings swallow quotes", idents == ["let", "x", "let", "y"], str(idents))
    toks = lex("fn f<'a>(x: &'a str) -> char { 'a' }")
    check(
        "lexer: lifetimes vs chars",
        sum(1 for t in toks if t.kind == LIFETIME) == 2
        and sum(1 for t in toks if t.kind == CHAR) == 1,
    )
    toks = lex("/* a /* b */ c */ ident")
    check("lexer: nested block comments", len(toks) == 2 and toks[1].text == "ident")
    toks = lex("a[0] + 1_000usize + 1.5 + 0x1F")
    ints = [t.text for t in toks if t.kind == INT]
    check("lexer: ints and floats", ints == ["0", "1_000usize", "0x1F"], str(ints))
    check("lexer: v[0] indexes with a plain int", lex("v[0]")[2].is_plain_int())
    toks = lex("/* a\nb\nc */ x")
    check("lexer: multiline end_line", toks[0].end_line == 3 and toks[1].line == 3)

    src = (
        "fn prod() { x.unwrap(); }\n"
        "#[cfg(all(test, not(loom)))]\n"
        "mod tests {\n"
        "    fn t() { y.unwrap(); }\n"
        "}\n"
        "fn appended_after_tests() { z.unwrap(); }\n"
    )
    m = FileModel("f.rs", src)
    check(
        "model: cfg(test) item spans",
        (not m.test_line[1]) and all(m.test_line[l] for l in (2, 3, 4, 5)) and not m.test_line[6],
    )
    m = FileModel("f.rs", "#[cfg(not(test))]\nfn prod() { x.unwrap(); }\n")
    check("model: cfg(not(test)) is production", not m.test_line[2])
    m = FileModel("f.rs", "#[test]\nfn t() { x.unwrap(); }\nfn prod() {}\n")
    check("model: #[test] marks one fn", m.test_line[1] and m.test_line[2] and not m.test_line[3])
    src = (
        "// lint:allow(panic) — reason\n"
        "let row = ids\n"
        "    .iter()\n"
        "    .position(|id| id == w)\n"
        '    .expect("present");\n'
        "let other = q.unwrap();\n"
    )
    m = FileModel("f.rs", src)
    expect_i = next(i for i in range(m.ncode()) if m.tok(i).is_ident("expect"))
    unwrap_i = next(i for i in range(m.ncode()) if m.tok(i).is_ident("unwrap"))
    check(
        "model: statement attachment (not a window)",
        m.allowed(expect_i, "lint:allow(panic)") and not m.allowed(unwrap_i, "lint:allow(panic)"),
    )
    m = FileModel("f.rs", "shape[0] = n; // lint:allow(panic) — rank >= 1\n")
    idx = next(i for i in range(m.ncode()) if m.tok(i).is_punct("["))
    check("model: trailing comment attaches", m.allowed(idx, "lint:allow(panic)"))

    def run_snip(src):
        cfg = Config.fixtures("t.rs")
        return analyze_sources([("t.rs", src)], cfg)

    f = run_snip("use std::{collections::HashMap, sync::Mutex};\n")
    check("rules: grouped import caught", any(x.rule == "A1" and "std::sync" in x.msg for x in f))
    f = run_snip("use std::sync as s;\n")
    check("rules: aliased import caught", sum(1 for x in f if x.rule == "A1") == 1)
    f = run_snip("use std as s;\n")
    check("rules: renamed std root caught", sum(1 for x in f if x.rule == "A1") == 1)
    f = run_snip("use ::std::thread::spawn;\n")
    check("rules: leading :: caught", sum(1 for x in f if x.rule == "A1") == 1)
    f = run_snip("use std::collections::{HashMap, VecDeque};\nuse std::time::Duration;\n")
    check("rules: benign std imports pass", not f, "; ".join(x.render() for x in f))
    f = run_snip("fn f() { let m = std::sync::Mutex::new(0); }\n")
    check("rules: qualified expression path caught", sum(1 for x in f if x.rule == "A1") == 1)
    f = run_snip('// std::sync in prose\nfn f() -> &\'static str { "std::thread" }\n')
    check("rules: prose/strings do not trip A1", not f, "; ".join(x.render() for x in f))
    f = run_snip(
        "fn f(a: Admission) -> u32 {\n    match a {\n        Admission::Delivered => 1,\n"
        "        _ => 0,\n    }\n}\n"
    )
    check("rules: custody wildcard flagged", sum(1 for x in f if x.rule == "A5") == 1)
    f = run_snip(
        "fn g(v: u8) -> Option<QosClass> {\n    match v {\n        0 => Some(QosClass::Realtime),\n"
        "        _ => None,\n    }\n}\n"
    )
    check("rules: value-position enum wildcard passes", not f, "; ".join(x.render() for x in f))
    f = run_snip("fn f() {\n    let g = lock_unpoisoned(&m);\n    thread::sleep(d);\n}\n")
    check("rules: guard across sleep flagged", sum(1 for x in f if x.rule == "A4") == 1)
    f = run_snip(
        "fn f() {\n    let mut g = lock_unpoisoned(&m);\n"
        "    g = wait_unpoisoned(&cv, g); // loom-verified: loom_model_x\n}\n"
        "mod loom_tests { fn loom_model_x() {} }\n"
    )
    check("rules: wait handoff passes", not f, "; ".join(x.render() for x in f))


def fixture_checks(root):
    print("[2/3] fixture battery (tools/analyzer/fixtures)")
    fdir = os.path.join(root, "tools", "analyzer", "fixtures")
    names = sorted(fn for fn in os.listdir(fdir) if fn.endswith(".rs"))
    expected = {"a%d_%s.rs" % (i, kind) for i in range(1, 6) for kind in ("bad", "good")}
    expected |= {"a5_epoch_bad.rs", "a5_epoch_good.rs"}
    check("fixture set complete", set(names) == expected, str(sorted(set(names) ^ expected)))
    for name in names:
        with open(os.path.join(fdir, name), encoding="utf-8") as f:
            src = f.read()
        markers = set()
        for lineno, l in enumerate(src.splitlines(), 1):
            if "//~" in l:
                markers.add((lineno, l.split("//~", 1)[1].strip()))
        found = {
            (f.line, f.rule)
            for f in analyze_sources([(name, src)], Config.fixtures(name))
        }
        if name.endswith("_bad.rs"):
            check(
                "%s findings == markers" % name,
                markers and found == markers,
                "markers=%s found=%s" % (sorted(markers), sorted(found)),
            )
        else:
            check(
                "%s clean (and declares no markers)" % name,
                not markers and not found,
                "markers=%s found=%s" % (sorted(markers), sorted(found)),
            )


def tree_check(root):
    print("[3/3] real tree scan (rust/src)")
    findings = analyze_tree(root)
    for f in findings:
        print("    " + f.render())
    check("tree clean", not findings, "%d finding(s)" % len(findings))


def main():
    root = sys.argv[1] if len(sys.argv) > 1 else os.path.join(os.path.dirname(__file__), "..")
    unit_checks()
    fixture_checks(root)
    tree_check(root)
    if FAILURES:
        print("verify_analyzer: %d FAILURE(S): %s" % (len(FAILURES), ", ".join(FAILURES)))
        return 1
    print("verify_analyzer: all checks passed (port agrees with fixtures; tree clean)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
