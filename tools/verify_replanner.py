#!/usr/bin/env python3
"""Toolchain-free cross-check of the cost-drift replanner arithmetic.

A line-for-line Python port of `rust/src/coordinator/replan.rs`
(`DriftModel::observe`/`check`, `predicted_from_matrix`, `shape`) plus
the re-solve path it calls (`ordering::solve_subset` over the Held-Karp
DP from `ordering/held_karp.rs`), used on machines without a Rust
toolchain to:

  1. replay every scenario asserted by the unit suite in replan.rs
     (matching shape quiet, min-samples gate, inverted shape triggering,
     tenant/task routing, singleton tenants) so the constants baked into
     those tests are independently checked;
  2. pin the drift-trigger trace reported in BENCH_10.json: the toy
     3-task spec (columns 1/2/4) fed the inverted observation [4, 2, 1]
     must fire at max_drift exactly 3.0, rescale the matrix columns by
     [4, 1, 0.25], and stay quiet on the same observations post-reset.

Every float operation mirrors the Rust source ordering, so results are
bitwise-identical, not merely close. Drift between this file and
replan.rs is a bug in exactly one of them; `cargo test --lib
coordinator::replan::` is the ground truth once a toolchain is present.

Run: python3 tools/verify_replanner.py
"""

import itertools
import json
import sys

USIZE_MAX = -1  # stand-in for usize::MAX sentinel

# ---------------------------------------------------- ordering (port)


class OrderingProblem:
    """ordering/mod.rs::OrderingProblem, path objective by default."""

    def __init__(self, cost, precedence=None, conditional=None, cyclic=False):
        self.n = len(cost)
        self.cost = cost
        self.precedence = list(precedence or [])
        self.conditional = list(conditional or [])
        self.cyclic = cyclic

    def all_precedence(self):
        out = list(self.precedence)
        out.extend((a, b) for (a, b, _p) in self.conditional)
        return sorted(set(out))

    def exec_prob(self, t):
        p = 1.0
        for (_a, b, prob) in self.conditional:
            if b == t:
                p *= prob
        return p

    def prereq_masks(self):
        m = [0] * self.n
        for (a, b) in self.all_precedence():
            m[b] |= 1 << a
        return m

    def fitness(self, order):
        f = 0.0
        for (a, b) in zip(order, order[1:]):
            f += self.exec_prob(b) * self.cost[a][b]
        if self.cyclic and len(order) > 1:
            f += self.exec_prob(order[0]) * self.cost[order[-1]][order[0]]
        return f

    def is_valid(self, order):
        if len(order) != self.n or sorted(order) != list(range(self.n)):
            return False
        pos = {t: i for i, t in enumerate(order)}
        return all(pos[a] < pos[b] for (a, b) in self.all_precedence())


def solve_held_karp(p):
    """ordering/held_karp.rs::solve_held_karp — same dp/parent layout,
    same strict `<` update, same ascending mask/j/k iteration, so tie
    breaks match the Rust solver exactly."""
    assert p.n <= 20, "Held-Karp capped at 20 tasks"
    if p.n == 0:
        return ([], 0.0)
    if p.n == 1:
        return ([0], 0.0)
    n = p.n
    full = (1 << n) - 1
    prereq = p.prereq_masks()
    inf = float("inf")
    dp = [inf] * ((full + 1) * n)
    parent = [USIZE_MAX] * ((full + 1) * n)

    def idx(mask, j):
        return mask * n + j

    for j in range(n):
        if prereq[j] != 0:
            continue
        if p.cyclic and j != 0:
            continue
        dp[idx(1 << j, j)] = 0.0

    for mask in range(1, full + 1):
        for j in range(n):
            if mask & (1 << j) == 0:
                continue
            cur = dp[idx(mask, j)]
            if cur == inf:
                continue
            for k in range(n):
                mk = 1 << k
                if mask & mk != 0 or prereq[k] & ~mask & full != 0:
                    continue
                cand = cur + p.exec_prob(k) * p.cost[j][k]
                slot = idx(mask | mk, k)
                if cand < dp[slot]:
                    dp[slot] = cand
                    parent[slot] = j

    best_end, best_cost = None, inf
    for j in range(n):
        c = dp[idx(full, j)]
        if p.cyclic:
            c += p.exec_prob(0) * p.cost[j][0]
        if c < best_cost:
            best_cost = c
            best_end = j
    if best_end is None or best_cost == inf:
        return None
    j = best_end
    order = [j]
    mask = full
    while bin(mask).count("1") > 1:
        pj = parent[idx(mask, j)]
        assert pj != USIZE_MAX
        mask &= ~(1 << j)
        j = pj
        order.append(j)
    order.reverse()
    return (order, best_cost)


def solve_subset(cost, tasks, precedence, conditional):
    """ordering/mod.rs::solve_subset — restrict, remap, solve, map back."""
    if not tasks:
        return None
    local = [USIZE_MAX] * len(cost)
    for i, t in enumerate(tasks):
        if t >= len(cost) or local[t] != USIZE_MAX:
            return None
        local[t] = i
    sub_cost = [[cost[a][b] for b in tasks] for a in tasks]
    sub_prec = [
        (local[a], local[b])
        for (a, b) in precedence
        if a < len(local) and b < len(local)
        and local[a] != USIZE_MAX and local[b] != USIZE_MAX
    ]
    sub_cond = [
        (local[a], local[b], pr)
        for (a, b, pr) in conditional
        if a < len(local) and b < len(local)
        and local[a] != USIZE_MAX and local[b] != USIZE_MAX
    ]
    solved = solve_held_karp(OrderingProblem(sub_cost, sub_prec, sub_cond))
    if solved is None:
        return None
    order, c = solved
    return ([tasks[i] for i in order], c)


# ---------------------------------------------------- replan.rs (port)


def predicted_from_matrix(cost, tasks):
    """predicted[i] = mean over j != i of cost[tasks[j]][tasks[i]]."""
    k = len(tasks)
    out = []
    for into in tasks:
        if k < 2:
            out.append(0.0)
            continue
        s = 0.0
        for frm in tasks:
            if frm != into:
                s += cost[frm][into]
        out.append(s / (k - 1))
    return out


def shape(v):
    """Normalize to mean 1.0; all-zero stays all-zero."""
    mean = sum(v) / max(len(v), 1)
    if mean <= 0.0:
        return list(v)
    return [x / mean for x in v]


class TenantSpec:
    def __init__(self, tenant, tasks, cost, precedence=(), conditional=()):
        self.tenant = tenant
        self.tasks = list(tasks)
        self.cost = [list(row) for row in cost]
        self.precedence = list(precedence)
        self.conditional = list(conditional)


class TenantState:
    def __init__(self, spec, n_tasks):
        self.spec = spec
        self.local = [USIZE_MAX] * n_tasks
        for i, t in enumerate(spec.tasks):
            if t < n_tasks:
                self.local[t] = i
        k = len(spec.tasks)
        self.predicted = predicted_from_matrix(spec.cost, spec.tasks)
        self.ewma = [None] * k
        self.samples = [0] * k

    def reset(self):
        self.predicted = predicted_from_matrix(self.spec.cost, self.spec.tasks)
        self.ewma = [None] * len(self.ewma)
        self.samples = [0] * len(self.samples)


class DriftModel:
    """replan.rs::DriftModel — observe() folds one sample, check() is
    the drift-trigger arithmetic kept in lockstep with the Rust fn."""

    def __init__(self, specs, threshold=0.5, min_samples=32, alpha=0.2):
        self.threshold = threshold
        self.min_samples = min_samples
        self.alpha = alpha
        n_tasks = max((len(s.cost) for s in specs), default=0)
        self.tenants = [TenantState(s, n_tasks) for s in specs]

    def observe(self, tenant, task, secs):
        a = self.alpha
        ti = next(
            (i for i, t in enumerate(self.tenants) if t.spec.tenant == tenant),
            None,
        )
        if ti is None:
            return None
        st = self.tenants[ti]
        if task >= len(st.local):
            return None
        pos = st.local[task]
        if pos == USIZE_MAX:
            return None
        e = st.ewma[pos]
        st.ewma[pos] = secs if e is None else (1.0 - a) * e + a * secs
        st.samples[pos] += 1
        return self.check(ti)

    def check(self, ti):
        st = self.tenants[ti]
        k = len(st.spec.tasks)
        if k < 2:
            return None
        if any(s < self.min_samples for s in st.samples):
            return None
        observed = [0.0 if e is None else e for e in st.ewma]
        p_hat = shape(st.predicted)
        o_hat = shape(observed)
        max_drift = 0.0
        for i in range(k):
            denom = max(p_hat[i], 1e-12)
            d = abs(o_hat[i] - p_hat[i]) / denom
            if d > max_drift:
                max_drift = d
        if max_drift <= self.threshold:
            return None
        # confirmed: rescale matrix columns by observed/predicted ratio
        for i in range(k):
            m = o_hat[i] / max(p_hat[i], 1e-12)
            col = st.spec.tasks[i]
            for row in st.spec.cost:
                if col < len(row):
                    row[col] *= m
        solved = solve_subset(
            st.spec.cost, st.spec.tasks, st.spec.precedence,
            st.spec.conditional,
        )
        order = solved[0] if solved else list(st.spec.tasks)
        conditional = [
            (x, y)
            for (x, y, _p) in st.spec.conditional
            if x in st.spec.tasks and y in st.spec.tasks
        ]
        tenant = st.spec.tenant
        st.reset()
        return (tenant, order, conditional, max_drift)


# ----------------------------------------------------------- scenarios


def toy_spec(tenant=0):
    """replan.rs test spec: switching into task 2 modeled 4x task 0."""
    return TenantSpec(
        tenant,
        [0, 1, 2],
        [
            [0.0, 2.0, 4.0],
            [1.0, 0.0, 4.0],
            [1.0, 2.0, 0.0],
        ],
    )


def toy_model(**kw):
    kw.setdefault("threshold", 0.5)
    kw.setdefault("min_samples", 2)
    kw.setdefault("alpha", 1.0)
    return DriftModel([toy_spec()], **kw)


def feed(model, tenant, costs, rounds):
    fired = None
    for _ in range(rounds):
        for task, secs in enumerate(costs):
            hit = model.observe(tenant, task, secs)
            if hit is not None:
                fired = hit
    return fired


def check_predicted_column_means():
    got = predicted_from_matrix(toy_spec().cost, [0, 1, 2])
    assert got == [1.0, 2.0, 4.0], got
    # subset restriction: tasks {0, 2} see only each other's columns
    got = predicted_from_matrix(toy_spec().cost, [0, 2])
    assert got == [1.0, 4.0], got
    assert predicted_from_matrix(toy_spec().cost, [1]) == [0.0]


def check_shape_normalizes_to_mean_one():
    s = shape([1.0, 2.0, 4.0])
    assert abs(sum(s) / 3 - 1.0) < 1e-15, s
    assert s == [3.0 / 7.0, 6.0 / 7.0, 12.0 / 7.0], s
    assert shape([0.0, 0.0]) == [0.0, 0.0]


def check_matching_shape_never_triggers():
    # same shape scaled 3x: a uniform slowdown reordering cannot help
    assert feed(toy_model(), 0, [3.0, 6.0, 12.0], 8) is None


def check_quiet_below_min_samples():
    m = toy_model(min_samples=50)
    assert feed(m, 0, [9.0, 0.1, 0.1], 20) is None


def check_inverted_costs_trigger():
    m = toy_model()
    hit = feed(m, 0, [4.0, 2.0, 1.0], 4)
    assert hit is not None, "inverted shape must trigger"
    tenant, order, conditional, max_drift = hit
    assert tenant == 0
    # o_hat [12/7, 6/7, 3/7] vs p_hat [3/7, 6/7, 12/7]: drift on task 0
    # is (12/7 - 3/7) / (3/7) = exactly 3.0, and it is the max
    assert max_drift == 3.0, max_drift
    assert sorted(order) == [0, 1, 2], order
    assert conditional == []
    # columns rescaled by o_hat/p_hat = [4, 1, 0.25]
    st = m.tenants[0]
    assert st.spec.cost == [
        [0.0, 2.0, 1.0],
        [4.0, 0.0, 1.0],
        [4.0, 2.0, 0.0],
    ], st.spec.cost
    # the re-solve sees the rescaled matrix: best path cost is 3.0
    solved = solve_subset(st.spec.cost, [0, 1, 2], [], [])
    assert solved[0] == order and solved[1] == 3.0, solved
    # post-reset the rescaled matrix IS the model (predicted [4, 2, 1]):
    # the same observations are now on-shape and must stay quiet
    assert st.predicted == [4.0, 2.0, 1.0], st.predicted
    assert feed(m, 0, [4.0, 2.0, 1.0], 8) is None


def check_observations_route_by_tenant():
    two = TenantSpec(1, [0, 1], toy_spec().cost)
    m = DriftModel([toy_spec(0), two], threshold=0.5, min_samples=2,
                   alpha=1.0)
    assert m.observe(7, 0, 9.0) is None  # unknown tenant
    assert m.observe(0, 9, 9.0) is None  # nobody's task
    assert m.observe(1, 2, 9.0) is None  # foreign task for tenant 1
    assert m.tenants[1].samples == [0, 0]


def check_singleton_tenants_never_replan():
    one = TenantSpec(0, [1], toy_spec().cost)
    m = DriftModel([one], threshold=0.5, min_samples=2, alpha=1.0)
    for _ in range(20):
        assert m.observe(0, 1, 99.0) is None


def check_ewma_smoothing():
    # alpha 0.5: 8, then (0.5*8 + 0.5*0) = 4, then 2 — folds, not replaces
    m = toy_model(alpha=0.5, min_samples=100)
    for _ in range(3):
        m.observe(0, 0, 8.0 if m.tenants[0].samples[0] == 0 else 0.0)
    assert m.tenants[0].ewma[0] == 2.0, m.tenants[0].ewma


def check_held_karp_matches_brute_force():
    # deterministic LCG instances: the DP port must agree with an
    # exhaustive permutation scan on cost, and produce a valid order
    state = 12345
    for _case in range(12):
        vals = []
        for _ in range(25):
            state = (state * 6364136223846793005 + 1442695040888963407) % (
                1 << 64
            )
            vals.append((state >> 33) % 1000 / 10.0)
        n = 4
        cost = [[0.0 if i == j else vals.pop() for j in range(n)]
                for i in range(n)]
        p = OrderingProblem(cost, precedence=[(0, 2)])
        order, c = solve_held_karp(p)
        assert p.is_valid(order), order
        best = min(
            p.fitness(list(perm))
            for perm in itertools.permutations(range(n))
            if p.is_valid(list(perm))
        )
        assert abs(c - best) < 1e-9, (c, best)


def check_solve_subset_remaps_and_filters():
    cost = [
        [0.0, 1.0, 4.0],
        [1.0, 0.0, 2.0],
        [4.0, 2.0, 0.0],
    ]
    order, c = solve_subset(cost, [0, 2], [(2, 0), (1, 0)], [])
    assert order == [2, 0] and c == 4.0, (order, c)
    order, c = solve_subset(cost, [0, 2], [], [(0, 2, 0.5)])
    assert order == [0, 2] and c == 2.0, (order, c)
    assert solve_subset(cost, [], [], []) is None
    assert solve_subset(cost, [0, 0], [], []) is None
    assert solve_subset(cost, [0, 7], [], []) is None
    assert solve_subset(cost, [0, 1], [(0, 1), (1, 0)], []) is None
    assert solve_subset(cost, [1], [], []) == ([1], 0.0)


CHECKS = [
    ("predicted = column means over the subset", check_predicted_column_means),
    ("shape normalizes to mean 1.0", check_shape_normalizes_to_mean_one),
    ("matching shape never triggers", check_matching_shape_never_triggers),
    ("quiet below min_samples", check_quiet_below_min_samples),
    ("inverted costs trigger at drift 3.0", check_inverted_costs_trigger),
    ("observations route by tenant", check_observations_route_by_tenant),
    ("singleton tenants never replan", check_singleton_tenants_never_replan),
    ("EWMA folds with alpha", check_ewma_smoothing),
    ("Held-Karp port matches brute force", check_held_karp_matches_brute_force),
    ("solve_subset remaps and filters", check_solve_subset_remaps_and_filters),
]


def trigger_trace():
    """The BENCH_10.json drift-trigger pin, derived not transcribed."""
    m = toy_model()
    hit = feed(m, 0, [4.0, 2.0, 1.0], 4)
    _tenant, order, _cond, max_drift = hit
    return {
        "spec_column_means": [1.0, 2.0, 4.0],
        "observed": [4.0, 2.0, 1.0],
        "max_drift": max_drift,
        "column_rescale": [4.0, 1.0, 0.25],
        "replanned_order": order,
        "replanned_path_cost": solve_subset(
            m.tenants[0].spec.cost, [0, 1, 2], [], []
        )[1],
        "quiet_after_reset": feed(m, 0, [4.0, 2.0, 1.0], 8) is None,
    }


def main():
    failed = 0
    for name, fn in CHECKS:
        try:
            fn()
            print(f"  ok  {name}")
        except AssertionError as e:
            failed += 1
            print(f"FAIL  {name}: {e}")
    if failed:
        print(f"{failed} of {len(CHECKS)} replanner checks FAILED")
        return 1
    print(f"all {len(CHECKS)} replanner checks pass")
    print(json.dumps(trigger_trace(), indent=2))
    return 0


if __name__ == "__main__":
    sys.exit(main())
